//! Open-loop serving benchmark + the committed SLO snapshot
//! (`cargo bench --bench bench_serve`).
//!
//! Emits `../BENCH_serve.json` (repo root): the continuous-batching
//! front-end vs the wave-drain baseline on the sim backend, one seeded
//! arrival trace per rate, swept across three arrival rates that bracket
//! the configured capacity (slots × batch / service ≈ 160 rows/s):
//! underload, near-saturation, and 2× overload. Each point records
//! p50/p99/mean/max latency, goodput (in-deadline completions per
//! virtual second), shed and deadline-violation counts, batch count and
//! refill count, plus real decode wall time.
//!
//! Snapshot schema, like `BENCH_store.json`:
//!   * `record` — deterministic echo of the serving geometry (batch,
//!     slots, budgets, service model, trace shape); `--check` recomputes
//!     it and fails on drift, so a config change forces a re-measure
//!     instead of silently invalidating the numbers;
//!   * `rates` — one row per arrival rate with a `continuous` and a
//!     `wave` section, gated by `--check`: served + shed == offered and
//!     goodput == (served − violations)/horizon per section, refills ==
//!     batches (one `begin_refill` per scheduled batch), zero violations
//!     in continuous mode (its dispatches are deadline-bounded by
//!     construction), zero shed at the underload rate in both modes, and
//!     under overload both modes shed while continuous goodput stays at
//!     or above wave-drain goodput — the floor the tentpole must beat.
//!
//! All gated quantities except `wall_ms` live on the virtual clock of the
//! pure `serving::frontend::schedule` loop, so a full run reproduces them
//! exactly from the seeded trace; wall_ms measures this machine only.
//!
//! Modes:
//!   cargo bench --bench bench_serve              # run + rewrite snapshot
//!   cargo bench --bench bench_serve -- --check   # validate committed
//!                                                # snapshot (ci.sh gate)

use tinylora_rl::adapters::packing::Precision;
use tinylora_rl::engine::InferenceEngine;
use tinylora_rl::runtime::{Runtime, SIM_SCHEME, SIM_TIER};
use tinylora_rl::serving::{
    AdapterStore, ArrivalTrace, Frontend, FrontendConfig, SchedPolicy, SloStats, TraceConfig,
};
use tinylora_rl::util::json::{num, obj, s, Value};
use tinylora_rl::util::Pcg64;
use tinylora_rl::weights::WeightSet;

/// Committed snapshot path (repo root; cargo bench runs from `rust/`).
/// Override with TINYLORA_BENCH_SERVE for scratch runs.
fn snapshot_path() -> String {
    std::env::var("TINYLORA_BENCH_SERVE").unwrap_or_else(|_| "../BENCH_serve.json".into())
}

const SCHEMA_VERSION: usize = 1;
/// Arrival rates swept (requests/s). Capacity of the configured plane is
/// slots × batch / service_base = 2 × 4 / 0.05 = 160 rows/s, so the
/// sweep brackets it: comfortable underload, near saturation, 2× over.
const RATES: [f64; 3] = [40.0, 120.0, 320.0];
const N_REQUESTS: usize = 400;
const TENANTS: usize = 24;
const ZIPF_S: f64 = 1.1;
const BURST: usize = 1;
const TRACE_SEED: u64 = 4242;
const BATCH: usize = 4;
const SLOTS: usize = 2;
const DEADLINE: f64 = 0.4;
const MAX_WAIT: f64 = 0.05;
const SERVICE_BASE: f64 = 0.05;
const SERVICE_PER_ROW: f64 = 0.0;
/// Relative tolerance for committed derived quantities (goodput,
/// occupancy) against their defining ratios.
const REL_TOL: f64 = 0.01;

fn frontend_cfg(continuous: bool) -> FrontendConfig {
    FrontendConfig {
        batch: BATCH,
        slots: SLOTS,
        deadline: DEADLINE,
        max_wait: MAX_WAIT,
        service_base: SERVICE_BASE,
        service_per_row: SERVICE_PER_ROW,
        policy: SchedPolicy::DeadlineFlush,
        continuous,
    }
}

fn trace_cfg(rate: f64) -> TraceConfig {
    TraceConfig {
        seed: TRACE_SEED,
        n: N_REQUESTS,
        rate,
        burst: BURST,
        tenants: TENANTS,
        zipf_s: ZIPF_S,
        suite: "gsm8k-syn".into(),
    }
}

// ---------------------------------------------------------------------------
// Measurement
// ---------------------------------------------------------------------------

fn mode_section(slo: &SloStats, refills: u64, wall_ms: f64) -> Value {
    obj(vec![
        ("served", num(slo.served as f64)),
        ("shed", num(slo.shed as f64)),
        ("violations", num(slo.violations as f64)),
        ("batches", num(slo.batches as f64)),
        ("refills", num(refills as f64)),
        ("p50_latency", num(slo.p50_latency)),
        ("p99_latency", num(slo.p99_latency)),
        ("mean_latency", num(slo.mean_latency)),
        ("max_latency", num(slo.max_latency)),
        ("goodput", num(slo.goodput)),
        ("mean_occupancy", num(slo.mean_occupancy)),
        ("horizon", num(slo.horizon)),
        ("wall_ms", num(wall_ms)),
    ])
}

/// One arrival-rate point: generate the seeded trace once, then serve it
/// through the continuous front-end and the wave-drain baseline with
/// identical stores, decoding every batch through the sim backend.
fn run_rate(rt: &Runtime, base: &WeightSet, rate: f64) -> Value {
    let trace = ArrivalTrace::generate(&trace_cfg(rate)).expect("trace generation");
    let dir = std::env::temp_dir().join("tlrl_bench_serve");
    std::fs::create_dir_all(&dir).expect("scratch dir");
    let mut sections = Vec::new();
    for continuous in [true, false] {
        let mut store = AdapterStore::with_tiers(SIM_TIER, 4, 32);
        let mut rng = Pcg64::new(11);
        for name in trace.tenant_names() {
            let theta: Vec<f32> = (0..13).map(|_| rng.normal() * 0.01).collect();
            store.register(&name, SIM_SCHEME, &theta, Precision::Bf16).expect("register");
        }
        let mut fe = Frontend::new(rt, store, base.clone(), frontend_cfg(continuous), dir.clone())
            .expect("frontend");
        let plan = fe.serve_trace(rt, &trace).expect("serve");
        let slo = fe.slo(&plan);
        println!(
            "rate {rate:>4.0}/s {}: served {:>3}/{} shed {:>3} p50 {:.3}s p99 {:.3}s goodput {:>6.1}/s occ {:.2} wall {:.0}ms",
            if continuous { "continuous" } else { "wave      " },
            slo.served,
            slo.offered,
            slo.shed,
            slo.p50_latency,
            slo.p99_latency,
            slo.goodput,
            slo.mean_occupancy,
            fe.wall_ms(),
        );
        sections.push(mode_section(&slo, fe.store.stats().refills, fe.wall_ms()));
    }
    let wave = sections.pop().unwrap();
    let continuous = sections.pop().unwrap();
    obj(vec![
        ("rate", num(rate)),
        ("offered", num(N_REQUESTS as f64)),
        ("continuous", continuous),
        ("wave", wave),
    ])
}

// ---------------------------------------------------------------------------
// Snapshot schema
// ---------------------------------------------------------------------------

/// Deterministic echo of the serving geometry the numbers were measured
/// at. `--check` recomputes this; drift fails the gate so stale numbers
/// can never masquerade as current after a config change.
fn record_section(engine: &InferenceEngine) -> Value {
    obj(vec![
        ("batch", num(BATCH as f64)),
        ("slots", num(SLOTS as f64)),
        ("deadline", num(DEADLINE)),
        ("max_wait", num(MAX_WAIT)),
        ("service_base", num(SERVICE_BASE)),
        ("service_per_row", num(SERVICE_PER_ROW)),
        ("geometries", Value::Arr(engine.geometries().iter().map(|&g| num(g as f64)).collect())),
        ("requests", num(N_REQUESTS as f64)),
        ("tenants", num(TENANTS as f64)),
        ("zipf_s", num(ZIPF_S)),
        ("burst", num(BURST as f64)),
        ("seed", num(TRACE_SEED as f64)),
        ("suite", s("gsm8k-syn")),
        ("rates", Value::Arr(RATES.iter().map(|&r| num(r)).collect())),
    ])
}

fn getf(v: &Value, key: &str) -> Result<f64, String> {
    v.get(key).and_then(|x| x.f64()).map_err(|e| format!("{key}: {e:#}"))
}

fn getu(v: &Value, key: &str) -> Result<u64, String> {
    let x = getf(v, key)?;
    if x < 0.0 || x.fract() != 0.0 {
        return Err(format!("{key} not a count: {x}"));
    }
    Ok(x as u64)
}

fn rel_close(a: f64, b: f64) -> bool {
    (a - b).abs() <= REL_TOL * b.abs().max(1e-12)
}

/// Gate one mode section. `continuous` switches on the structural
/// guarantees only the refill loop makes.
fn check_mode(m: &Value, continuous: bool) -> Result<(), String> {
    let served = getu(m, "served")?;
    let shed = getu(m, "shed")?;
    let violations = getu(m, "violations")?;
    let batches = getu(m, "batches")?;
    let refills = getu(m, "refills")?;
    if served + shed != N_REQUESTS as u64 {
        return Err(format!("served {served} + shed {shed} != offered {N_REQUESTS}"));
    }
    if violations > served {
        return Err(format!("violations {violations} > served {served}"));
    }
    if continuous && violations != 0 {
        return Err(format!(
            "continuous mode reported {violations} deadline violations — refill \
             dispatches are deadline-bounded by construction"
        ));
    }
    if refills != batches {
        return Err(format!(
            "refills {refills} != batches {batches} — the front-end pays exactly \
             one begin_refill per scheduled batch"
        ));
    }
    // every batch holds 1..=BATCH real rows
    if batches < served.div_ceil(BATCH as u64) || batches > served.max(1) {
        return Err(format!("batches {batches} impossible for {served} served rows"));
    }
    let p50 = getf(m, "p50_latency")?;
    let p99 = getf(m, "p99_latency")?;
    let mean = getf(m, "mean_latency")?;
    let max = getf(m, "max_latency")?;
    // latency = queue wait + virtual service, so it is floored by the
    // service model and the quantiles must be ordered
    if !(SERVICE_BASE <= p50 && p50 <= p99 && p99 <= max) {
        return Err(format!("latency quantiles broken: p50 {p50} p99 {p99} max {max}"));
    }
    if !(SERVICE_BASE <= mean && mean <= max) {
        return Err(format!("mean latency {mean} outside [{SERVICE_BASE}, {max}]"));
    }
    if continuous {
        // refill dispatch wait < deadline, so latency < deadline + service
        let bound = DEADLINE + SERVICE_BASE + SERVICE_PER_ROW * BATCH as f64 + 1e-9;
        if max >= bound {
            return Err(format!(
                "continuous max latency {max} >= deadline-bounded ceiling {bound}"
            ));
        }
    }
    let horizon = getf(m, "horizon")?;
    if !(horizon > 0.0 && horizon.is_finite()) {
        return Err(format!("horizon not positive: {horizon}"));
    }
    let goodput = getf(m, "goodput")?;
    let want = (served - violations) as f64 / horizon;
    if !rel_close(goodput, want) {
        return Err(format!(
            "goodput {goodput} != (served − violations)/horizon = {want:.4}"
        ));
    }
    let occ = getf(m, "mean_occupancy")?;
    let want_occ = served as f64 / (batches * BATCH as u64) as f64;
    if !(occ > 0.0 && occ <= 1.0) || !rel_close(occ, want_occ) {
        return Err(format!("mean_occupancy {occ} != served/(batches×batch) = {want_occ:.4}"));
    }
    let wall = getf(m, "wall_ms")?;
    if !(wall > 0.0 && wall.is_finite()) {
        return Err(format!("wall_ms not positive: {wall}"));
    }
    Ok(())
}

fn validate_schema(v: &Value, record_want: &Value) -> Result<(), String> {
    let get = |key: &str| v.get(key).map_err(|e| format!("{e:#}"));
    if get("kind")?.str().map_err(|e| format!("kind: {e:#}"))? != "bench_serve" {
        return Err("kind != bench_serve".into());
    }
    let version = get("schema_version")?.usize().map_err(|e| format!("schema_version: {e:#}"))?;
    if version != SCHEMA_VERSION {
        return Err(format!("schema_version {version} != {SCHEMA_VERSION}"));
    }
    let provenance = get("provenance")?.str().map_err(|e| format!("provenance: {e:#}"))?;
    if provenance != "estimate" && provenance != "measured" {
        return Err(format!("provenance {provenance:?} not in {{estimate, measured}}"));
    }
    let record = get("record")?;
    if record != record_want {
        return Err(format!(
            "record drift: committed {} != recomputed {} — the serving geometry \
             or trace shape changed; rerun `cargo bench --bench bench_serve` \
             and commit the refreshed snapshot",
            record.to_string(),
            record_want.to_string()
        ));
    }
    let rows = get("rates")?.arr().map(|a| a.to_vec()).map_err(|e| format!("rates: {e:#}"))?;
    if rows.len() != RATES.len() {
        return Err(format!("rates has {} rows, expected {}", rows.len(), RATES.len()));
    }
    for (i, (row, &rate)) in rows.iter().zip(&RATES).enumerate() {
        let ctx = |e: String| format!("rate={rate}: {e}");
        if getf(row, "rate").map_err(&ctx)? != rate {
            return Err(ctx("rate echo mismatch".into()));
        }
        if getu(row, "offered").map_err(&ctx)? != N_REQUESTS as u64 {
            return Err(ctx(format!("offered != {N_REQUESTS}")));
        }
        let cont = row.get("continuous").map_err(|e| ctx(format!("{e:#}")))?;
        let wave = row.get("wave").map_err(|e| ctx(format!("{e:#}")))?;
        check_mode(cont, true).map_err(|e| ctx(format!("continuous: {e}")))?;
        check_mode(wave, false).map_err(|e| ctx(format!("wave: {e}")))?;
        let (c_shed, w_shed) = (getu(cont, "shed").unwrap(), getu(wave, "shed").unwrap());
        if i == 0 && (c_shed != 0 || w_shed != 0) {
            return Err(ctx(format!(
                "underload rate shed requests (continuous {c_shed}, wave {w_shed}) — \
                 shedding must only trigger past the deadline budget"
            )));
        }
        if i == RATES.len() - 1 {
            if c_shed == 0 || w_shed == 0 {
                return Err(ctx(format!(
                    "overload rate shed nothing (continuous {c_shed}, wave {w_shed}) — \
                     the sweep no longer exercises admission control"
                )));
            }
            let (c_good, w_good) = (getf(cont, "goodput").unwrap(), getf(wave, "goodput").unwrap());
            if c_good < w_good {
                return Err(ctx(format!(
                    "continuous goodput {c_good:.1}/s fell below wave-drain {w_good:.1}/s \
                     under overload — the refill loop must dominate the barrier"
                )));
            }
        }
    }
    Ok(())
}

/// `--check`: committed snapshot must be schema-valid, geometry-current
/// and inside every SLO consistency gate; prints the committed tally that
/// ci.sh surfaces in its full-mode report.
fn check_snapshot(path: &str, record_want: &Value) -> Result<(), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let v = Value::parse(text.trim()).map_err(|e| format!("parsing {path}: {e:#}"))?;
    validate_schema(&v, record_want)?;
    let provenance = v.get("provenance").and_then(|x| x.str().map(String::from)).unwrap();
    println!("serve snapshot provenance: {provenance}");
    let rows = v.get("rates").map_err(|e| format!("{e:#}"))?.arr().unwrap().to_vec();
    for row in &rows {
        let rate = getf(row, "rate").unwrap();
        for mode in ["continuous", "wave"] {
            let m = row.get(mode).unwrap();
            println!(
                "serve (committed): rate {rate:>4.0}/s {mode:<10} served {:>3} shed {:>3} \
                 p50 {:.3}s p99 {:.3}s goodput {:>6.1}/s",
                getu(m, "served").unwrap(),
                getu(m, "shed").unwrap(),
                getf(m, "p50_latency").unwrap(),
                getf(m, "p99_latency").unwrap(),
                getf(m, "goodput").unwrap(),
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// main
// ---------------------------------------------------------------------------

fn main() {
    let check = std::env::args().any(|a| a == "--check");
    let path = snapshot_path();
    let rt = Runtime::sim(1).expect("sim runtime");
    let tier = rt.manifest.tier(SIM_TIER).expect("sim tier").clone();
    let base = WeightSet::init(&tier, 3).expect("sim base weights");
    let engine = InferenceEngine::new(&rt, SIM_TIER, BATCH).expect("engine");
    let record = record_section(&engine);
    if check {
        match check_snapshot(&path, &record) {
            Ok(()) => println!("BENCH_serve.json: schema + record + SLO gates OK ({path})"),
            Err(e) => {
                eprintln!("BENCH_serve.json check failed: {e}");
                std::process::exit(1);
            }
        }
        return;
    }

    println!("== open-loop serving benchmarks (sim backend) ==\n");
    let mut rows = Vec::new();
    for &rate in &RATES {
        rows.push(run_rate(&rt, &base, rate));
    }

    let snapshot = obj(vec![
        ("kind", s("bench_serve")),
        ("schema_version", num(SCHEMA_VERSION as f64)),
        ("provenance", s("measured")),
        ("record", record.clone()),
        ("rates", Value::Arr(rows)),
    ]);
    if let Err(e) = validate_schema(&snapshot, &record) {
        eprintln!("generated snapshot failed its own schema: {e}");
        std::process::exit(1);
    }
    std::fs::write(&path, snapshot.to_string() + "\n").expect("writing snapshot");
    println!("\nperf snapshot -> {path}");
}
