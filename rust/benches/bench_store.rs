//! Tiered adapter-store benchmark + the committed capacity/latency
//! snapshot (`cargo bench --bench bench_store`).
//!
//! Emits `../BENCH_store.json` (repo root): the million-tenant plane
//! measured on the sim backend — registration throughput, resident cold
//! bytes, per-tier checkout latency (hot hit / warm hit / cold miss) and
//! the tier-transition profile of a zipf-distributed request trace, at
//! 10^3 / 10^5 / 10^6 tenants.
//!
//! Snapshot schema, like `BENCH_SIM.json`:
//!   * `record` — deterministic echo of the storage geometry (scheme,
//!     packed record width, merged-model bytes, tier knobs); `--check`
//!     recomputes it and fails on drift, so a packing or tier-knob
//!     change forces a re-measure instead of silently invalidating the
//!     numbers;
//!   * `scales` — one row per tenant count, gated by `--check`:
//!     `stored_bytes == record_bytes × tenants` EXACTLY (the 26-byte
//!     headline), total (data + index) ≤ 128 B/tenant, hot-hit checkout
//!     strictly cheaper than both merge paths, and a trace whose
//!     per-tier hits sum to its accesses with demotions observed.
//!
//! Modes:
//!   cargo bench --bench bench_store              # run + rewrite snapshot
//!   cargo bench --bench bench_store -- --check   # validate committed
//!                                                # snapshot (ci.sh gate)

use std::path::Path;

use tinylora_rl::adapters::packing::{pack, Precision};
use tinylora_rl::runtime::sim::N_THETA;
use tinylora_rl::runtime::{Runtime, SIM_SCHEME, SIM_TIER};
use tinylora_rl::serving::AdapterStore;
use tinylora_rl::util::json::{num, obj, s, Value};
use tinylora_rl::util::{Pcg64, Timer};
use tinylora_rl::weights::WeightSet;

/// Committed snapshot path (repo root; cargo bench runs from `rust/`).
/// Override with TINYLORA_BENCH_STORE for scratch runs.
fn snapshot_path() -> String {
    std::env::var("TINYLORA_BENCH_STORE").unwrap_or_else(|_| "../BENCH_store.json".into())
}

const SCHEMA_VERSION: usize = 1;
/// Tenant populations swept (the 10^6 point is the paper's claim).
const SCALES: [usize; 3] = [1_000, 100_000, 1_000_000];
/// Requests per zipf trace.
const TRACE_LEN: usize = 4000;
/// Zipf skew of the trace (a few tenants dominate, like real serving).
const ZIPF_S: f64 = 1.1;
/// Hot-tier capacity (merged models resident at once).
const MAX_RESIDENT: usize = 8;
/// Warm-tier capacity (unpacked theta vectors).
const MAX_WARM: usize = 64;
/// Timed checkouts per latency point.
const MICRO_OPS: usize = 48;
/// Documented memory bound: total cold (data + index) bytes per tenant.
/// 26 B of packed record + a compact interned index must stay under
/// this at every scale — 128 B × 10^6 tenants = 128 MB worst case.
const BYTES_PER_TENANT_BOUND: f64 = 128.0;

fn tenant_name(i: usize) -> String {
    format!("t{i}")
}

/// Inverse-CDF sample of a (continuous-approximation) zipf(s) rank on
/// `1..=n`, mapped to a 0-based tenant index.
fn zipf_idx(rng: &mut Pcg64, n: usize) -> usize {
    let u = rng.uniform() as f64;
    let x = (1.0 + u * ((n as f64).powf(1.0 - ZIPF_S) - 1.0)).powf(1.0 / (1.0 - ZIPF_S));
    (x as usize).saturating_sub(1).min(n - 1)
}

// ---------------------------------------------------------------------------
// Measurement
// ---------------------------------------------------------------------------

/// One tenant-population point: register `n` tenants, measure per-tier
/// checkout latency under controlled residency, then profile a zipf
/// trace through the tier machinery.
fn run_scale(rt: &Runtime, base: &WeightSet, n: usize, dir: &Path) -> Value {
    let mut store = AdapterStore::with_tiers(SIM_TIER, MAX_RESIDENT, MAX_WARM);
    let mut rng = Pcg64::new(99);
    let mut theta = [0.0f32; N_THETA];

    let t = Timer::start();
    for i in 0..n {
        theta[i % N_THETA] = rng.uniform() - 0.5;
        store.register(&tenant_name(i), SIM_SCHEME, &theta, Precision::Bf16).unwrap();
    }
    let register_s = t.secs();

    let st0 = store.stats();
    assert_eq!(
        st0.stored_bytes,
        store.recompute_stored_bytes(),
        "stored_bytes counter drifted from the arena scan"
    );
    let bytes_per_tenant = (st0.stored_bytes + st0.cold_index_bytes) as f64 / n as f64;

    // hot hit: resident merged model, checkout is a clone
    store.activate(rt, base, "t0", dir).unwrap();
    let t = Timer::start();
    for _ in 0..MICRO_OPS {
        std::hint::black_box(store.activate(rt, base, "t0", dir).unwrap());
    }
    let us_hot = t.secs() * 1e6 / MICRO_OPS as f64;

    // warm hit: flood the hot tier with MAX_RESIDENT fillers so the
    // probe adapter demotes to warm, then time its re-merge
    store.activate(rt, base, "t1", dir).unwrap();
    let mut warm_secs = 0.0;
    for _ in 0..MICRO_OPS {
        for j in 2..2 + MAX_RESIDENT {
            store.activate(rt, base, &tenant_name(j), dir).unwrap();
        }
        let t = Timer::start();
        std::hint::black_box(store.activate(rt, base, "t1", dir).unwrap());
        warm_secs += t.secs();
    }
    let us_warm = warm_secs * 1e6 / MICRO_OPS as f64;

    // cold miss: never-touched tail tenants, unpack + merge per op
    let mut cold_secs = 0.0;
    for k in 0..MICRO_OPS {
        let name = tenant_name(n - 1 - k);
        let t = Timer::start();
        std::hint::black_box(store.activate(rt, base, &name, dir).unwrap());
        cold_secs += t.secs();
    }
    let us_cold = cold_secs * 1e6 / MICRO_OPS as f64;

    // zipf trace through a clean counter window (residency warm-started
    // by the microbenches above, like a store that has been serving)
    store.reset_stats();
    let mut zrng = Pcg64::new(777);
    for _ in 0..TRACE_LEN {
        let idx = zipf_idx(&mut zrng, n);
        store.activate(rt, base, &tenant_name(idx), dir).unwrap();
    }
    let ts = store.stats();

    println!(
        "n={n:<8} register {register_s:>7.3}s  cold {}B (+{}B index, {bytes_per_tenant:.1} B/tenant)",
        st0.stored_bytes, st0.cold_index_bytes
    );
    println!(
        "          checkout us: hot {us_hot:>8.1}  warm {us_warm:>8.1}  cold {us_cold:>8.1}"
    );
    println!(
        "          trace: hot/warm/cold {}/{}/{}  demotions {}  evictions hot/warm {}/{}",
        ts.hot_hits, ts.warm_hits, ts.cold_misses, ts.demotions, ts.evictions_hot, ts.evictions_warm
    );

    obj(vec![
        ("tenants", num(n as f64)),
        ("register_s", num(register_s)),
        ("stored_bytes", num(st0.stored_bytes as f64)),
        ("cold_index_bytes", num(st0.cold_index_bytes as f64)),
        ("bytes_per_tenant", num(bytes_per_tenant)),
        (
            "checkout_us",
            obj(vec![
                ("hot_hit", num(us_hot)),
                ("warm_hit", num(us_warm)),
                ("cold_miss", num(us_cold)),
            ]),
        ),
        (
            "trace",
            obj(vec![
                ("accesses", num(TRACE_LEN as f64)),
                ("zipf_s", num(ZIPF_S)),
                ("hot_hits", num(ts.hot_hits as f64)),
                ("warm_hits", num(ts.warm_hits as f64)),
                ("cold_misses", num(ts.cold_misses as f64)),
                ("promotions_hot", num(ts.promotions_hot as f64)),
                ("demotions", num(ts.demotions as f64)),
                ("evictions_hot", num(ts.evictions_hot as f64)),
                ("evictions_warm", num(ts.evictions_warm as f64)),
                ("hot_bytes", num(ts.hot_bytes as f64)),
                ("warm_bytes", num(ts.warm_bytes as f64)),
            ]),
        ),
    ])
}

// ---------------------------------------------------------------------------
// Snapshot schema
// ---------------------------------------------------------------------------

/// Deterministic echo of the storage geometry the numbers were measured
/// at. `--check` recomputes this; drift fails the gate so stale numbers
/// can never masquerade as current after a packing/knob change.
fn record_section(base: &WeightSet) -> Value {
    obj(vec![
        ("scheme", s(SIM_SCHEME)),
        ("n_theta", num(N_THETA as f64)),
        ("precision", s("bf16")),
        ("record_bytes", num(pack(&[0.0f32; N_THETA], Precision::Bf16).len() as f64)),
        ("model_bytes", num((base.n_params() * 4) as f64)),
        ("max_resident", num(MAX_RESIDENT as f64)),
        ("max_warm", num(MAX_WARM as f64)),
        ("trace_len", num(TRACE_LEN as f64)),
        ("zipf_s", num(ZIPF_S)),
        ("scales", Value::Arr(SCALES.iter().map(|&x| num(x as f64)).collect())),
    ])
}

fn pos_finite(v: &Value, key: &str) -> Result<f64, String> {
    let x = v.get(key).and_then(|x| x.f64()).map_err(|e| format!("{key}: {e:#}"))?;
    if !x.is_finite() || x <= 0.0 {
        return Err(format!("{key} not positive: {x}"));
    }
    Ok(x)
}

fn validate_schema(v: &Value, record_want: &Value) -> Result<(), String> {
    let get = |key: &str| v.get(key).map_err(|e| format!("{e:#}"));
    if get("kind")?.str().map_err(|e| format!("kind: {e:#}"))? != "bench_store" {
        return Err("kind != bench_store".into());
    }
    let version = get("schema_version")?.usize().map_err(|e| format!("schema_version: {e:#}"))?;
    if version != SCHEMA_VERSION {
        return Err(format!("schema_version {version} != {SCHEMA_VERSION}"));
    }
    let provenance = get("provenance")?.str().map_err(|e| format!("provenance: {e:#}"))?;
    if provenance != "estimate" && provenance != "measured" {
        return Err(format!("provenance {provenance:?} not in {{estimate, measured}}"));
    }
    let record = get("record")?;
    if record != record_want {
        return Err(format!(
            "record drift: committed {} != recomputed {} — the packed-record \
             geometry or tier knobs changed; rerun `cargo bench --bench \
             bench_store` and commit the refreshed snapshot",
            record.to_string(),
            record_want.to_string()
        ));
    }
    let record_bytes =
        record.get("record_bytes").and_then(|x| x.usize()).map_err(|e| format!("{e:#}"))?;
    let model_bytes =
        record.get("model_bytes").and_then(|x| x.usize()).map_err(|e| format!("{e:#}"))?;
    let rows =
        get("scales")?.arr().map(|a| a.to_vec()).map_err(|e| format!("scales: {e:#}"))?;
    if rows.len() != SCALES.len() {
        return Err(format!("scales has {} rows, expected {}", rows.len(), SCALES.len()));
    }
    for (row, &n) in rows.iter().zip(&SCALES) {
        let ctx = |e: String| format!("n={n}: {e}");
        let tenants =
            row.get("tenants").and_then(|x| x.usize()).map_err(|e| ctx(format!("{e:#}")))?;
        if tenants != n {
            return Err(ctx(format!("tenants {tenants} != {n}")));
        }
        pos_finite(row, "register_s").map_err(ctx)?;
        let stored =
            row.get("stored_bytes").and_then(|x| x.usize()).map_err(|e| ctx(format!("{e:#}")))?;
        if stored != record_bytes * n {
            return Err(ctx(format!(
                "stored_bytes {stored} != record_bytes {record_bytes} × tenants {n} — \
                 the cold tier must cost exactly one packed record per tenant"
            )));
        }
        let index = row
            .get("cold_index_bytes")
            .and_then(|x| x.usize())
            .map_err(|e| ctx(format!("{e:#}")))?;
        if index == 0 {
            return Err(ctx("cold_index_bytes is 0 (index unaccounted)".into()));
        }
        let bpt = pos_finite(row, "bytes_per_tenant").map_err(ctx)?;
        let want_bpt = (stored + index) as f64 / n as f64;
        if (bpt - want_bpt).abs() > 0.01 * want_bpt {
            return Err(ctx(format!(
                "bytes_per_tenant {bpt:.2} inconsistent with (stored+index)/tenants {want_bpt:.2}"
            )));
        }
        if bpt > BYTES_PER_TENANT_BOUND {
            return Err(ctx(format!(
                "bytes_per_tenant {bpt:.1} exceeds the documented {BYTES_PER_TENANT_BOUND} B bound"
            )));
        }
        let us = row.get("checkout_us").map_err(|e| ctx(format!("{e:#}")))?;
        let hot = pos_finite(us, "hot_hit").map_err(ctx)?;
        let warm = pos_finite(us, "warm_hit").map_err(ctx)?;
        let cold = pos_finite(us, "cold_miss").map_err(ctx)?;
        // hot checkout skips the merge entirely — it must beat both
        // merge paths. warm-vs-cold is merge-dominated on sim (the
        // unpack it saves is 13 values), so no ordering gate there.
        if hot >= warm || hot >= cold {
            return Err(ctx(format!(
                "hot-hit checkout {hot:.1}us is not cheaper than warm {warm:.1}us / \
                 cold {cold:.1}us — the hot tier is not paying for itself"
            )));
        }
        let tr = row.get("trace").map_err(|e| ctx(format!("{e:#}")))?;
        let tget = |key: &str| {
            tr.get(key).and_then(|x| x.usize()).map_err(|e| ctx(format!("trace.{key}: {e:#}")))
        };
        let accesses = tget("accesses")?;
        if accesses != TRACE_LEN {
            return Err(ctx(format!("trace.accesses {accesses} != {TRACE_LEN}")));
        }
        let (hot_hits, warm_hits, cold_misses) =
            (tget("hot_hits")?, tget("warm_hits")?, tget("cold_misses")?);
        if hot_hits + warm_hits + cold_misses != accesses {
            return Err(ctx(format!(
                "trace tier hits {hot_hits}+{warm_hits}+{cold_misses} do not sum to \
                 accesses {accesses}"
            )));
        }
        if tget("demotions")? == 0 || tget("evictions_hot")? == 0 {
            return Err(ctx(
                "trace saw no hot evictions/demotions — the tier machinery was not exercised"
                    .into(),
            ));
        }
        if tget("hot_bytes")? > MAX_RESIDENT * model_bytes {
            return Err(ctx(format!(
                "trace.hot_bytes {} exceeds max_resident × model_bytes {}",
                tget("hot_bytes")?,
                MAX_RESIDENT * model_bytes
            )));
        }
    }
    Ok(())
}

/// `--check`: committed snapshot must be schema-valid, geometry-current
/// and inside every capacity/latency gate; prints the committed tally
/// that ci.sh surfaces in its full-mode report.
fn check_snapshot(path: &str, record_want: &Value) -> Result<(), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let v = Value::parse(text.trim()).map_err(|e| format!("parsing {path}: {e:#}"))?;
    validate_schema(&v, record_want)?;
    let provenance = v.get("provenance").and_then(|x| x.str().map(String::from)).unwrap();
    println!("store snapshot provenance: {provenance}");
    let rows = v.get("scales").map_err(|e| format!("{e:#}"))?.arr().unwrap().to_vec();
    for row in &rows {
        let n = row.get("tenants").and_then(|x| x.usize()).unwrap();
        let bpt = row.get("bytes_per_tenant").and_then(|x| x.f64()).unwrap();
        let us = row.get("checkout_us").unwrap();
        println!(
            "store (committed): n={n:<8} {bpt:>5.1} B/tenant  checkout us \
             hot {:>7.1} warm {:>7.1} cold {:>7.1}",
            us.get("hot_hit").and_then(|x| x.f64()).unwrap(),
            us.get("warm_hit").and_then(|x| x.f64()).unwrap(),
            us.get("cold_miss").and_then(|x| x.f64()).unwrap(),
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// main
// ---------------------------------------------------------------------------

fn main() {
    let check = std::env::args().any(|a| a == "--check");
    let path = snapshot_path();
    let rt = Runtime::sim(1).expect("sim runtime");
    let tier = rt.manifest.tier(SIM_TIER).expect("sim tier").clone();
    let base = WeightSet::init(&tier, 3).expect("sim base weights");
    let record = record_section(&base);
    if check {
        match check_snapshot(&path, &record) {
            Ok(()) => println!("BENCH_store.json: schema + record + capacity gates OK ({path})"),
            Err(e) => {
                eprintln!("BENCH_store.json check failed: {e}");
                std::process::exit(1);
            }
        }
        return;
    }

    println!("== tiered adapter-store benchmarks (sim backend) ==\n");
    let dir = std::env::temp_dir().join("tlrl_bench_store");
    std::fs::create_dir_all(&dir).expect("scratch dir");
    let mut rows = Vec::new();
    for &n in &SCALES {
        rows.push(run_scale(&rt, &base, n, &dir));
    }

    let snapshot = obj(vec![
        ("kind", s("bench_store")),
        ("schema_version", num(SCHEMA_VERSION as f64)),
        ("provenance", s("measured")),
        ("record", record.clone()),
        ("scales", Value::Arr(rows)),
    ]);
    if let Err(e) = validate_schema(&snapshot, &record) {
        eprintln!("generated snapshot failed its own schema: {e}");
        std::process::exit(1);
    }
    std::fs::write(&path, snapshot.to_string() + "\n").expect("writing snapshot");
    println!("\nperf snapshot -> {path}");
}
