//! Benchmark harness (`cargo bench`) — criterion is unavailable in the
//! offline image, so this is a self-contained harness: warmup + N timed
//! iterations, reporting mean/min/max per benchmark.
//!
//! Covers the runtime hot paths behind every figure/table driver:
//! prefill/decode/generate dispatch latency and rollout throughput, the
//! GRPO gradient + merge step per scheme, and the pure-rust substrates
//! (SVD, packing, task generation, verification, batcher, advantage).

use std::path::Path;

use tinylora_rl::adapters::factors::FactorSet;
use tinylora_rl::adapters::packing::{pack, unpack, Precision};
use tinylora_rl::adapters::svd::truncated_svd;
use tinylora_rl::coordinator::advantage::group_advantages;
use tinylora_rl::coordinator::policy::{GrpoHp, Policy};
use tinylora_rl::coordinator::rollout::RolloutEngine;
use tinylora_rl::engine::scheduler::{QueuedRequest, SchedPolicy, Scheduler};
use tinylora_rl::serving::{DynamicBatcher, Request};
use tinylora_rl::tasks::corpus::{pretrain_batch, prompt_batch};
use tinylora_rl::tasks::generator::SUITES;
use tinylora_rl::tasks::verifier::reward;
use tinylora_rl::tensor::{Arg, TensorI32};
use tinylora_rl::tokenizer::Tokenizer;
use tinylora_rl::util::{timer::time_iters, Pcg64};
use tinylora_rl::weights::WeightSet;
use tinylora_rl::Runtime;

struct Bench {
    rows: Vec<(String, f64, f64, f64, String)>,
}

/// Batch-formation at queue depth: the seed single-queue `DynamicBatcher`
/// (rescans the whole queue per batch — O(n²)) against the engine's
/// per-adapter-queue `Scheduler` (O(#adapters) per batch), same policy
/// semantics (occupancy-first), 32 tenants, batch size 8.
fn bench_scheduler(b: &mut Bench) {
    const ADAPTERS: u64 = 32;
    for &(n_req, iters) in &[(1_000u64, 20usize), (10_000u64, 3usize)] {
        b.run(
            &format!("batcher/single-queue drain {n_req} reqs"),
            iters,
            "seed baseline",
            || {
                let mut batcher = DynamicBatcher::new(8, 0.1);
                for i in 0..n_req {
                    batcher.push(Request {
                        id: i,
                        adapter: format!("t{}", i % ADAPTERS),
                        prompt: String::new(),
                        arrival: i as f64 * 1e-4,
                    });
                }
                let mut n = 0u64;
                while let Some(batch) = batcher.next_batch(1e9) {
                    n += batch.requests.len() as u64;
                }
                assert_eq!(n, n_req);
            },
        );
        b.run(
            &format!("scheduler/per-adapter drain {n_req} reqs"),
            iters,
            "engine::Scheduler",
            || {
                let mut s = Scheduler::new(8, 0.1, SchedPolicy::OccupancyFirst);
                for i in 0..n_req {
                    s.push(QueuedRequest {
                        id: i,
                        adapter: format!("t{}", i % ADAPTERS),
                        prompt: String::new(),
                        arrival: i as f64 * 1e-4,
                    });
                }
                let mut n = 0u64;
                while let Some(batch) = s.next_batch(1e9) {
                    n += batch.requests.len() as u64;
                }
                assert_eq!(n, n_req);
            },
        );
    }
    let old = b.rows.iter().find(|r| r.0.contains("single-queue drain 10000")).unwrap().1;
    let new = b.rows.iter().find(|r| r.0.contains("per-adapter drain 10000")).unwrap().1;
    println!(
        "scheduler speedup @10k: {:.1}x (single-queue {:.2} ms -> per-adapter {:.2} ms)\n",
        old / new,
        old,
        new
    );
    assert!(new < old, "per-adapter scheduler must beat the single-queue batcher at 10k");
}

impl Bench {
    fn run<F: FnMut()>(&mut self, name: &str, iters: usize, note: &str, mut f: F) {
        f(); // warmup
        let (mean, min, max) = time_iters(iters, &mut f);
        println!("{name:<44} mean {mean:>9.3} ms  (min {min:>9.3}, max {max:>9.3})  {note}");
        self.rows.push((name.to_string(), mean, min, max, note.to_string()));
    }
}

fn main() {
    let mut b = Bench { rows: Vec::new() };
    println!("== tinylora-rl benchmarks (1 CPU core, PJRT CPU backend) ==\n");

    // ---------------- pure-rust substrates ----------------
    let mut rng = Pcg64::new(1);
    let tok = Tokenizer::new();

    b.run("tasks/generate gsm8k-syn problem", 2000, "", || {
        std::hint::black_box(SUITES[0].generate(&mut rng));
    });
    let p = SUITES[0].generate(&mut Pcg64::new(2));
    b.run("tasks/verify response", 5000, "", || {
        std::hint::black_box(reward(&p.gold, p.answer));
    });
    b.run("tokenizer/encode+decode 60 chars", 5000, "", || {
        let ids = tok.encode(&p.prompt);
        std::hint::black_box(tok.decode(&ids));
    });
    let w: Vec<f32> = Pcg64::new(3).normal_vec(64 * 128, 1.0);
    b.run("svd/truncated r=2 64x128", 20, "subspace iteration", || {
        std::hint::black_box(truncated_svd(&w, 64, 128, 2, 7));
    });
    let theta: Vec<f32> = Pcg64::new(4).normal_vec(4096, 0.1);
    b.run("packing/pack+unpack 4096 bf16", 2000, "", || {
        let bytes = pack(&theta, Precision::Bf16);
        std::hint::black_box(unpack(&bytes, Precision::Bf16));
    });
    let rewards: Vec<f32> = (0..256).map(|i| (i % 3 == 0) as u8 as f32).collect();
    b.run("advantage/256 rewards group=4", 5000, "", || {
        std::hint::black_box(group_advantages(&rewards, 4));
    });
    b.run("batcher/push+drain 256 reqs 8 tenants", 200, "", || {
        let mut batcher = DynamicBatcher::new(8, 0.1);
        for i in 0..256u64 {
            batcher.push(Request {
                id: i,
                adapter: format!("t{}", i % 8),
                prompt: String::new(),
                arrival: i as f64 * 0.01,
            });
        }
        let mut n = 0;
        while let Some(batch) = batcher.next_batch(1e9) {
            n += batch.requests.len();
        }
        assert_eq!(n, 256);
    });

    bench_scheduler(&mut b);

    // ---------------- PJRT runtime paths ----------------
    if !Path::new("artifacts/manifest.json").exists() {
        println!("\nartifacts not built — skipping runtime benches");
        return;
    }
    let rt = Runtime::new(Path::new("artifacts")).expect("runtime");
    let tier = rt.manifest.tier("micro").unwrap().clone();
    let ckpt = Path::new("ckpts").join("micro.ckpt");
    let weights = if ckpt.exists() {
        WeightSet::load(&ckpt).unwrap()
    } else {
        WeightSet::init(&tier, 0).unwrap()
    };
    let mut rng = Pcg64::new(5);

    // compile timings (one-time cost per executable)
    let t0 = std::time::Instant::now();
    let gen_exe_name = rt.manifest.generate_exe("micro", rt.manifest.batch.roll).unwrap().name.clone();
    rt.load(&gen_exe_name).unwrap();
    println!("\ncompile generate_b32: {:.0} ms (one-time)", t0.elapsed().as_secs_f64() * 1e3);

    let engine = RolloutEngine::new(&rt, "micro", rt.manifest.batch.roll).unwrap();
    let problems: Vec<_> = (0..8).map(|_| SUITES[0].generate(&mut rng)).collect();
    let pb = prompt_batch(&problems, &tok, 4, engine.t_prefill);

    let mut roll = None;
    b.run("rollout/generate b32 s64 (fused loop)", 5, "2048 tokens/call", || {
        roll = Some(engine.rollout(&rt, &weights, &pb, &tok, 1.0, &mut rng).unwrap());
    });
    let roll = roll.unwrap();
    b.run("rollout/train-batch assembly b32", 200, "", || {
        std::hint::black_box(engine.train_batch(&pb, &roll, tier.t_train));
    });

    // per-step decode path (serving plane; per-token dispatch)
    let prefill_exe = rt
        .load(&rt.manifest.find("prefill", |e| e.fn_kind == "prefill" && e.tier == "micro" && e.batch == 8).unwrap().name)
        .unwrap();
    let decode_exe = rt
        .load(&rt.manifest.find("decode", |e| e.fn_kind == "decode" && e.tier == "micro" && e.batch == 8).unwrap().name)
        .unwrap();
    let mut args: Vec<Arg> = weights.args();
    args.push(Arg::I32(TensorI32::from_vec(&[8, tier.t_prefill], vec![3; 8 * tier.t_prefill])));
    args.push(Arg::I32(TensorI32::from_vec(&[8], vec![10; 8])));
    let mut kv = None;
    b.run("serving/prefill b8 t64", 10, "", || {
        let out = rt.run(&prefill_exe, &args).unwrap();
        kv = Some(out.f32(1).unwrap());
    });
    let kv = kv.unwrap();
    let mut dargs: Vec<Arg> = weights.args();
    dargs.push(Arg::F32(kv));
    dargs.push(Arg::I32(TensorI32::from_vec(&[8], vec![10; 8])));
    dargs.push(Arg::I32(TensorI32::from_vec(&[8], vec![5; 8])));
    b.run("serving/decode step b8 (tuple-literal I/O)", 10, "see §Perf note", || {
        std::hint::black_box(rt.run(&decode_exe, &dargs).unwrap());
    });

    // gradient + merge per scheme (the training hot path)
    for tag in ["tinylora_r2_u13_all", "xs_r4", "lora_r1", "full"] {
        let policy =
            Policy::new(&rt, "micro", tag, "grpo", weights.clone(), 0, Path::new("ckpts")).unwrap();
        let batch = engine.train_batch(&pb, &roll, tier.t_train);
        let hp = GrpoHp { clip_c: 4.0, kl_coef: 0.0 };
        b.run(
            &format!("grpo/grad b32 t128 {tag}"),
            3,
            &format!("{} params", policy.trainable_params()),
            || {
                std::hint::black_box(policy.grad(&rt, &batch, hp).unwrap());
            },
        );
    }
    let mut policy =
        Policy::new(&rt, "micro", "tinylora_r2_u13_all", "grpo", weights.clone(), 0, Path::new("ckpts"))
            .unwrap();
    b.run("adapter/merge 13-param tinylora", 10, "", || {
        policy.remerge(&rt).unwrap();
    });

    // factors (SVD over the whole model)
    b.run("factors/full-model SVD r=2 (micro)", 3, "21 modules", || {
        std::hint::black_box(FactorSet::compute(&tier, &weights, 2).unwrap());
    });

    // pretrain grad
    let pre_exe = rt
        .load(&rt.manifest.find("pretrain", |e| e.fn_kind == "pretrain" && e.tier == "micro").unwrap().name)
        .unwrap();
    let (tokens, mask) =
        pretrain_batch(&SUITES[0], &tok, &mut rng, rt.manifest.batch.train, tier.t_train);
    let mut pargs: Vec<Arg> = weights.args();
    pargs.push(Arg::I32(tokens));
    pargs.push(Arg::F32(mask));
    b.run("pretrain/grad b32 t128 (full params)", 3, "", || {
        std::hint::black_box(rt.run(&pre_exe, &pargs).unwrap());
    });

    // throughput summary
    let gen_ms = b.rows.iter().find(|r| r.0.starts_with("rollout/generate")).unwrap().1;
    println!(
        "\nrollout throughput: {:.0} tokens/s (32 seqs x 64 tokens / {:.0} ms)",
        32.0 * 64.0 / (gen_ms / 1e3),
        gen_ms
    );
    let grad_ms = b
        .rows
        .iter()
        .find(|r| r.0.contains("tinylora_r2_u13_all") && r.0.starts_with("grpo"))
        .unwrap()
        .1;
    println!(
        "GRPO step budget (13 params): rollout {:.0} ms + grad {:.0} ms = {:.0} ms/step",
        gen_ms, grad_ms, gen_ms + grad_ms
    );
}
