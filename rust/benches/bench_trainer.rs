//! Trainer-subsystem benchmarks (`cargo bench --bench bench_trainer`).
//!
//! Pure-rust parts always run: `TrainState` checkpoint roundtrips at the
//! 13-param headline and full-model sizes, and the adapter store's
//! access-ordered LRU against the seed's `Vec`-scan residency (the O(1)
//! touch/evict satellite). With artifacts built, the headline comparison
//! runs: serial vs multi-tenant GRPO training throughput at 4 and 16
//! tenants sharing one backbone — the wave decode fans across a
//! `WorkerPool` while grad/Adam stay per-tenant.

use std::path::Path;

use tinylora_rl::adapters::packing::Precision;
use tinylora_rl::coordinator::grpo::GrpoConfig;
use tinylora_rl::coordinator::optimizer::AdamState;
use tinylora_rl::metrics::RunLog;
use tinylora_rl::serving::ResidentLru;
use tinylora_rl::trainer::{TenantSpec, TenantTrainer, TrainState, TRAIN_STATE_VERSION};
use tinylora_rl::util::{timer::time_iters, Pcg64, Timer};
use tinylora_rl::weights::WeightSet;
use tinylora_rl::Runtime;

struct Bench {
    rows: Vec<(String, f64)>,
}

impl Bench {
    fn run<F: FnMut()>(&mut self, name: &str, iters: usize, note: &str, mut f: F) {
        f(); // warmup
        let (mean, min, max) = time_iters(iters, &mut f);
        println!("{name:<48} mean {mean:>9.3} ms  (min {min:>9.3}, max {max:>9.3})  {note}");
        self.rows.push((name.to_string(), mean));
    }
}

fn state_of_size(n: usize) -> TrainState {
    let mut rng = Pcg64::new(1);
    TrainState {
        version: TRAIN_STATE_VERSION,
        algo: "grpo".into(),
        tier: "micro".into(),
        scheme_tag: "tinylora_r2_u13_all".into(),
        config: "suite=gsm8k-syn lr=0.002 seed=0".into(),
        step: 40,
        rng: [1, 2, 3, 4],
        adam: AdamState { t: 40, m: rng.normal_vec(n, 0.1), v: rng.normal_vec(n, 0.1) },
        params: rng.normal_vec(n, 0.1),
    }
}

/// The seed's residency structure: a Vec scanned per touch, whole entries
/// moved to the MRU end. Kept only as the bench baseline.
fn vec_scan_lru(n_adapters: usize, touches: usize) {
    let mut resident: Vec<(String, u64)> = Vec::new();
    let max_resident = 8;
    let mut rng = Pcg64::new(7);
    for i in 0..touches {
        let name = format!("t{}", rng.below(n_adapters as u64));
        if let Some(pos) = resident.iter().position(|(n, _)| n == &name) {
            let entry = resident.remove(pos);
            resident.push(entry);
        } else {
            if resident.len() >= max_resident {
                resident.remove(0);
            }
            resident.push((name, i as u64));
        }
    }
    assert!(resident.len() <= max_resident);
}

fn access_ordered_lru(n_adapters: usize, touches: usize) {
    let mut lru: ResidentLru<u64> = ResidentLru::new();
    let max_resident = 8;
    let mut rng = Pcg64::new(7);
    for i in 0..touches {
        let name = format!("t{}", rng.below(n_adapters as u64));
        if lru.touch(&name).is_none() {
            lru.insert(&name, i as u64, max_resident);
        }
    }
    assert!(lru.len() <= max_resident);
}

fn bench_tenants(b: &mut Bench, rt: &Runtime, base: &WeightSet, g: usize, workers: usize) {
    let specs: Vec<TenantSpec> = (0..g)
        .map(|i| TenantSpec {
            name: format!("tenant-{i}"),
            scheme_tag: "tinylora_r2_u13_all".into(),
            cfg: GrpoConfig {
                steps: 2,
                group: 2,
                seed: i as u64,
                ..Default::default()
            },
            precision: Precision::Bf16,
        })
        .collect();
    let par_label = format!("{workers} workers");
    for (label, parallel, w) in
        [("serial", false, 1usize), (par_label.as_str(), true, workers)]
    {
        let mut tt = TenantTrainer::with_batch(
            rt,
            base,
            specs.clone(),
            w,
            Path::new("ckpts"),
            rt.manifest.batch.test,
        )
        .expect("tenant trainer");
        let t0 = Timer::start();
        tt.train(rt, &mut RunLog::null(), parallel).expect("train");
        let ms = t0.millis();
        println!(
            "tenants/{g:>2} x 2 steps, {label:<10} {ms:>9.0} ms  ({:.1} tenant-steps/s)",
            (g * 2) as f64 / (ms / 1e3)
        );
        b.rows.push((format!("tenants/{g}/{label}"), ms));
    }
}

fn main() {
    let mut b = Bench { rows: Vec::new() };
    println!("== trainer subsystem benchmarks ==\n");

    // ---------------- pure-rust substrates ----------------
    let dir = std::env::temp_dir().join("tlrl_bench_trainer");
    std::fs::create_dir_all(&dir).unwrap();
    let tiny = state_of_size(13);
    let tiny_path = dir.join("tiny.trainstate");
    b.run("trainstate/save+load 13 params", 500, "26-byte update", || {
        tiny.save(&tiny_path).unwrap();
        std::hint::black_box(TrainState::load(&tiny_path).unwrap());
    });
    let full = state_of_size(139_000);
    let full_path = dir.join("full.trainstate");
    b.run("trainstate/save+load 139k params", 20, "full-FT scale", || {
        full.save(&full_path).unwrap();
        std::hint::black_box(TrainState::load(&full_path).unwrap());
    });
    std::fs::remove_dir_all(&dir).ok();

    b.run("store/vec-scan lru 10k touches", 50, "seed baseline", || {
        vec_scan_lru(32, 10_000);
    });
    b.run("store/access-ordered lru 10k touches", 50, "O(1) touch/evict", || {
        access_ordered_lru(32, 10_000);
    });

    // ---------------- multi-tenant training (needs artifacts) ----------------
    if !Path::new("artifacts/manifest.json").exists() {
        println!("\nartifacts not built — skipping tenant-training benches");
        return;
    }
    let rt = Runtime::new(Path::new("artifacts")).expect("runtime");
    let tier = rt.manifest.tier("nano").expect("nano tier").clone();
    let ckpt = Path::new("ckpts").join("nano.ckpt");
    let base =
        if ckpt.exists() { WeightSet::load(&ckpt).unwrap() } else { WeightSet::init(&tier, 0).unwrap() };

    println!();
    bench_tenants(&mut b, &rt, &base, 4, 4);
    bench_tenants(&mut b, &rt, &base, 16, 4);

    for g in [4usize, 16] {
        let serial = b.rows.iter().find(|r| r.0 == format!("tenants/{g}/serial")).unwrap().1;
        let par = b.rows.iter().find(|r| r.0 == format!("tenants/{g}/4 workers")).unwrap().1;
        println!(
            "multi-tenant speedup @G={g}: {:.2}x (serial {serial:.0} ms -> pooled {par:.0} ms)",
            serial / par
        );
    }
}
