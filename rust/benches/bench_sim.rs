//! Sim execution-engine benchmark + the committed rows/s trajectory
//! (`cargo bench --bench bench_sim`).
//!
//! Emits `../BENCH_SIM.json` (repo root): rows/s of the vectorized,
//! row-parallel sim engine (`runtime::sim::exec`) on its three hot entry
//! points — `generate`, `logprobs`, `grpo_step` — over a batch ×
//! row-worker grid, against a FROZEN copy of the pre-split scalar
//! engine (per-position `mv()` with a fresh `Vec` per call, per-element
//! `pseudo_factor` hashing in merge/projection). The baseline lives in
//! this file on purpose: the library's scalar path is a `#[cfg(test)]`
//! differential oracle, and the baseline must stay fixed as the engine
//! improves — it IS the denominator of the trajectory.
//!
//! Snapshot schema, like `BENCH_runtime.json`:
//!   * `engine` — deterministic geometry echo (V/D/F, block lengths);
//!     `--check` recomputes it and fails on drift, so a geometry change
//!     forces a re-measure instead of silently invalidating the numbers;
//!   * `measured` — rows/s grids plus `speedup_generate_b32`, gated by
//!     `--check` at >= 2.0 (the vectorization floor this PR claims).
//!
//! Modes:
//!   cargo bench --bench bench_sim              # run + rewrite snapshot
//!   cargo bench --bench bench_sim -- --check   # validate committed
//!                                              # snapshot (ci.sh gate)

use tinylora_rl::runtime::sim::exec::{adapter_grads, generate, logprobs, GenInput, GrpoParams};
use tinylora_rl::runtime::sim::{
    merge_mats, project_dtheta, D, F, GEOMETRIES, MATS, N_GEN, N_THETA, SimModel, T_PREFILL,
    T_TRAIN, V,
};
use tinylora_rl::util::json::{num, obj, s, Value};
use tinylora_rl::util::{Pcg64, Timer};

/// Committed snapshot path (repo root; cargo bench runs from `rust/`).
/// Override with TINYLORA_BENCH_SIM for scratch runs.
fn snapshot_path() -> String {
    std::env::var("TINYLORA_BENCH_SIM").unwrap_or_else(|_| "../BENCH_SIM.json".into())
}

const SCHEMA_VERSION: usize = 1;
/// Batch sizes swept (rows per execute call).
const BATCHES: [usize; 3] = [1, 8, 32];
/// Row-worker counts swept (0/1 = serial; see `exec::chunk_ranges`).
const WORKERS: [usize; 3] = [1, 2, 4];
/// The scalar baseline is measured once, at the largest batch.
const SCALAR_BATCH: usize = 32;
/// Benchmarked entry points, snapshot order.
const OPS: [&str; 3] = ["generate", "logprobs", "grpo_step"];

// ---------------------------------------------------------------------------
// Frozen scalar baseline (the pre-split engine, verbatim algorithm)
// ---------------------------------------------------------------------------

/// The old engine, frozen: one position at a time, a fresh `Vec` per
/// `mv()` call, logits/softmax/backprop unfused, and per-element hash
/// recomputation in the merge and the dtheta projection. Do NOT
/// "improve" this module — speedups belong in `runtime::sim::exec`,
/// and this baseline is what they are measured against.
#[allow(clippy::needless_range_loop)]
mod scalar_baseline {
    use tinylora_rl::runtime::sim::{pseudo_factor, GAIN, MERGE_SCALE, N_THETA, SimModel, V};

    use super::{D, F, N_GEN, T_PREFILL, T_TRAIN};

    pub struct Acts {
        x: usize,
        h: Vec<f32>,
        tnh: Vec<f32>,
        vv: Vec<f32>,
        u: Vec<f32>,
        g: Vec<f32>,
        p: Vec<f32>,
        z: Vec<f32>,
    }

    pub struct Grads {
        pub embed: Vec<f32>,
        pub mats: [Vec<f32>; 7],
    }

    impl Grads {
        pub fn zeros() -> Self {
            Self {
                embed: vec![0.0; V * D],
                mats: [
                    vec![0.0; D * D],
                    vec![0.0; D * D],
                    vec![0.0; D * D],
                    vec![0.0; D * D],
                    vec![0.0; D * F],
                    vec![0.0; D * F],
                    vec![0.0; F * D],
                ],
            }
        }
    }

    /// y[j] = sum_i x[i] * w[i*d_out + j] — allocates the output, the
    /// per-position cost the arena-based engine removed.
    fn mv(w: &[f32], x: &[f32], d_out: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; d_out];
        for (i, &xi) in x.iter().enumerate() {
            let row = &w[i * d_out..(i + 1) * d_out];
            for j in 0..d_out {
                y[j] += xi * row[j];
            }
        }
        y
    }

    pub fn forward(m: &SimModel, tok: i32) -> (Acts, Vec<f32>) {
        let x = (tok.max(0) as usize).min(V - 1);
        let h = m.embed[x * D..(x + 1) * D].to_vec();
        let [wq, wk, wv, wo, wup, wgate, wdown] = m.mats;
        let sq = mv(wq, &h, D);
        let sk = mv(wk, &h, D);
        let tnh: Vec<f32> = (0..D).map(|j| (sq[j] + sk[j]).tanh()).collect();
        let vv = mv(wv, &tnh, D);
        let a = mv(wo, &vv, D);
        let u = mv(wup, &h, F);
        let g = mv(wgate, &h, F);
        let p: Vec<f32> = (0..F).map(|j| u[j] * g[j].tanh()).collect();
        let mm = mv(wdown, &p, D);
        let z: Vec<f32> = (0..D).map(|j| h[j] + a[j] + mm[j]).collect();
        let mut logits = vec![0.0f32; V];
        for v in 0..V {
            let ev = &m.embed[v * D..(v + 1) * D];
            let mut dot = 0.0f32;
            for j in 0..D {
                dot += z[j] * ev[j];
            }
            logits[v] = GAIN * dot;
        }
        (Acts { x, h, tnh, vv, u, g, p, z }, logits)
    }

    pub fn backward(m: &SimModel, acts: &Acts, dlogits: &[f32], grads: &mut Grads) {
        let [wq, wk, wv, wo, wup, wgate, wdown] = m.mats;
        let mut dz = vec![0.0f32; D];
        for v in 0..V {
            let dv = GAIN * dlogits[v];
            if dv == 0.0 {
                continue;
            }
            let ev = &m.embed[v * D..(v + 1) * D];
            for j in 0..D {
                dz[j] += dv * ev[j];
                grads.embed[v * D + j] += dv * acts.z[j];
            }
        }
        let mut dh = dz.clone();
        let dm = &dz;
        let da = &dz;
        let mut dp = vec![0.0f32; F];
        for i in 0..F {
            for j in 0..D {
                dp[i] += dm[j] * wdown[i * D + j];
                grads.mats[6][i * D + j] += acts.p[i] * dm[j];
            }
        }
        let mut du = vec![0.0f32; F];
        let mut dg = vec![0.0f32; F];
        for i in 0..F {
            let r = acts.g[i].tanh();
            du[i] = dp[i] * r;
            dg[i] = dp[i] * acts.u[i] * (1.0 - r * r);
        }
        for i in 0..D {
            for j in 0..F {
                grads.mats[4][i * F + j] += acts.h[i] * du[j];
                grads.mats[5][i * F + j] += acts.h[i] * dg[j];
                dh[i] += wup[i * F + j] * du[j] + wgate[i * F + j] * dg[j];
            }
        }
        let mut dvv = vec![0.0f32; D];
        for i in 0..D {
            for j in 0..D {
                dvv[i] += da[j] * wo[i * D + j];
                grads.mats[3][i * D + j] += acts.vv[i] * da[j];
            }
        }
        let mut dt = vec![0.0f32; D];
        for i in 0..D {
            for j in 0..D {
                dt[i] += dvv[j] * wv[i * D + j];
                grads.mats[2][i * D + j] += acts.tnh[i] * dvv[j];
            }
        }
        let ds: Vec<f32> =
            (0..D).map(|j| dt[j] * (1.0 - acts.tnh[j] * acts.tnh[j])).collect();
        for i in 0..D {
            for j in 0..D {
                grads.mats[0][i * D + j] += acts.h[i] * ds[j];
                grads.mats[1][i * D + j] += acts.h[i] * ds[j];
                dh[i] += (wq[i * D + j] + wk[i * D + j]) * ds[j];
            }
        }
        for j in 0..D {
            grads.embed[acts.x * D + j] += dh[j];
        }
    }

    fn softmax(logits: &[f32]) -> Vec<f32> {
        let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = logits.iter().map(|&l| (l - mx).exp()).collect();
        let sum: f32 = exps.iter().sum();
        exps.iter().map(|&e| e / sum).collect()
    }

    pub fn generate_rows(
        m: &SimModel,
        b: usize,
        tokens: &[i32],
        plen: &[i32],
        uniforms: &[f32],
        temperature: f32,
        out: (&mut [i32], &mut [f32]),
    ) {
        let (out_tokens, out_logp) = out;
        for i in 0..b {
            let p = (plen[i].max(1) as usize).min(T_PREFILL);
            let mut last = tokens[i * T_PREFILL + p - 1];
            for t in 0..N_GEN {
                let (_, logits) = forward(m, last);
                let scaled: Vec<f32> = logits.iter().map(|&l| l / temperature).collect();
                let probs = softmax(&scaled);
                let u = uniforms[i * N_GEN + t];
                let mut cum = 0.0f32;
                let mut chosen = V - 1;
                for v in 0..V {
                    cum += probs[v];
                    if u < cum {
                        chosen = v;
                        break;
                    }
                }
                out_tokens[i * N_GEN + t] = chosen as i32;
                out_logp[i * N_GEN + t] = probs[chosen].max(1e-30).ln();
                last = chosen as i32;
            }
        }
    }

    pub fn logprob_rows(m: &SimModel, b: usize, tokens: &[i32], out: &mut [f32]) {
        let t_len = T_TRAIN;
        for i in 0..b {
            for j in 0..t_len - 1 {
                let (_, logits) = forward(m, tokens[i * t_len + j]);
                let probs = softmax(&logits);
                let y = (tokens[i * t_len + j + 1].max(0) as usize).min(V - 1);
                out[i * (t_len - 1) + j] = probs[y].max(1e-30).ln();
            }
        }
    }

    fn merge_hashed(base: [&[f32]; 7], theta: &[f32]) -> [Vec<f32>; 7] {
        std::array::from_fn(|t| {
            let mut out = base[t].to_vec();
            for (j, w) in out.iter_mut().enumerate() {
                let mut delta = 0.0f32;
                for (k, &th) in theta.iter().enumerate() {
                    delta += th * pseudo_factor(t, k, j);
                }
                *w += MERGE_SCALE * delta;
            }
            out
        })
    }

    fn project_hashed(dmats: &[Vec<f32>; 7]) -> Vec<f32> {
        let mut dtheta = vec![0.0f32; N_THETA];
        for (t, dm) in dmats.iter().enumerate() {
            for (j, &dw) in dm.iter().enumerate() {
                if dw == 0.0 {
                    continue;
                }
                for (k, dt) in dtheta.iter_mut().enumerate() {
                    *dt += MERGE_SCALE * dw * pseudo_factor(t, k, j);
                }
            }
        }
        dtheta
    }

    /// One GRPO adapter-gradient step: hash-merge, per-position
    /// forward/backward, hash-projection. Returns dtheta.
    pub struct GrpoIn<'a> {
        pub tokens: &'a [i32],
        pub mask: &'a [f32],
        pub behavior: &'a [f32],
        pub advantages: &'a [f32],
        pub clip_c: f32,
        pub kl_coef: f32,
    }

    pub fn grpo_step(base: &SimModel, theta: &[f32], b: usize, inp: &GrpoIn) -> Vec<f32> {
        let merged = merge_hashed(base.mats, theta);
        let m = SimModel {
            embed: base.embed,
            mats: std::array::from_fn(|t| merged[t].as_slice()),
        };
        let t_len = T_TRAIN;
        let n: f32 = inp.mask.iter().sum::<f32>().max(1.0);
        let mut grads = Grads::zeros();
        let mut dlogits = vec![0.0f32; V];
        for i in 0..b {
            let adv = inp.advantages[i];
            for j in 0..t_len - 1 {
                let w = inp.mask[i * (t_len - 1) + j];
                if w == 0.0 {
                    continue;
                }
                let (acts, logits) = forward(&m, inp.tokens[i * t_len + j]);
                let probs = softmax(&logits);
                let y = (inp.tokens[i * t_len + j + 1].max(0) as usize).min(V - 1);
                let lp = probs[y].max(1e-30).ln();
                let beh = inp.behavior[i * (t_len - 1) + j];
                let ratio = (lp - beh).exp().min(1e6);
                let wt = if inp.clip_c > 0.0 { ratio.min(inp.clip_c) } else { ratio };
                let dl_dlp = (-wt * adv + inp.kl_coef * (ratio - 1.0)) * w / n;
                for v in 0..V {
                    let onehot = if v == y { 1.0 } else { 0.0 };
                    dlogits[v] = dl_dlp * (onehot - probs[v]);
                }
                backward(&m, &acts, &dlogits, &mut grads);
            }
        }
        project_hashed(&grads.mats)
    }
}

// ---------------------------------------------------------------------------
// Inputs + measurement harness
// ---------------------------------------------------------------------------

/// Shared seeded inputs at the largest batch; smaller batches slice.
struct Inputs {
    embed: Vec<f32>,
    mats: [Vec<f32>; 7],
    theta: Vec<f32>,
    tokens_gen: Vec<i32>,
    plen: Vec<i32>,
    uniforms: Vec<f32>,
    tokens_train: Vec<i32>,
    mask: Vec<f32>,
    behavior: Vec<f32>,
    advantages: Vec<f32>,
}

impl Inputs {
    fn seeded() -> Self {
        let b = SCALAR_BATCH;
        let mut rng = Pcg64::new(4242);
        let embed = rng.normal_vec(V * D, 0.1);
        let mats: [Vec<f32>; 7] =
            std::array::from_fn(|t| rng.normal_vec(MATS[t].1 * MATS[t].2, 0.3));
        Self {
            embed,
            mats,
            theta: rng.normal_vec(N_THETA, 0.2),
            tokens_gen: (0..b * T_PREFILL).map(|_| rng.below(V as u64) as i32).collect(),
            plen: (0..b).map(|_| 1 + rng.below(T_PREFILL as u64) as i32).collect(),
            uniforms: rng.uniform_vec(b * N_GEN),
            tokens_train: (0..b * T_TRAIN).map(|_| rng.below(V as u64) as i32).collect(),
            mask: (0..b * (T_TRAIN - 1))
                .map(|_| if rng.below(4) == 0 { 0.0 } else { 1.0 })
                .collect(),
            behavior: (0..b * (T_TRAIN - 1)).map(|_| -rng.uniform() * 3.0).collect(),
            advantages: (0..b).map(|_| rng.uniform() - 0.5).collect(),
        }
    }

    fn model(&self) -> SimModel<'_> {
        SimModel { embed: &self.embed, mats: std::array::from_fn(|t| self.mats[t].as_slice()) }
    }
}

/// rows/s of `f`, which processes `rows_per_call` rows per invocation:
/// one warmup call, then at least 3 calls and at least 0.15 s of wall
/// clock (whichever takes longer).
fn measure(rows_per_call: usize, mut f: impl FnMut()) -> f64 {
    f();
    let t = Timer::start();
    let mut calls = 0usize;
    loop {
        f();
        calls += 1;
        if calls >= 3 && t.secs() >= 0.15 {
            break;
        }
    }
    (calls * rows_per_call) as f64 / t.secs()
}

/// Measured grid of one op: rows/s at every batch × worker point.
fn sweep(name: &str, mut run: impl FnMut(usize, usize)) -> Vec<(usize, usize, f64)> {
    let mut grid = Vec::new();
    for &b in &BATCHES {
        for &w in &WORKERS {
            let rps = measure(b, || run(b, w));
            println!("{name:<10} b={b:<2} w={w}: {rps:>10.1} rows/s");
            grid.push((b, w, rps));
        }
    }
    grid
}

// ---------------------------------------------------------------------------
// Snapshot schema
// ---------------------------------------------------------------------------

/// Deterministic echo of the engine geometry the numbers were measured
/// at. `--check` recomputes this; drift fails the gate so stale rows/s
/// can never masquerade as current ones after a geometry change.
fn engine_section() -> Value {
    let ints = |xs: &[usize]| Value::Arr(xs.iter().map(|&x| num(x as f64)).collect());
    obj(vec![
        ("vocab", num(V as f64)),
        ("d", num(D as f64)),
        ("f", num(F as f64)),
        ("t_prefill", num(T_PREFILL as f64)),
        ("t_train", num(T_TRAIN as f64)),
        ("n_gen", num(N_GEN as f64)),
        ("theta", num(N_THETA as f64)),
        ("geometries", ints(&GEOMETRIES)),
    ])
}

fn op_section(grid: &[(usize, usize, f64)], scalar_rps: f64) -> Value {
    obj(vec![
        ("scalar_b32_rows_per_s", num(scalar_rps)),
        (
            "grid",
            Value::Arr(
                grid.iter()
                    .map(|&(b, w, rps)| {
                        obj(vec![
                            ("batch", num(b as f64)),
                            ("workers", num(w as f64)),
                            ("rows_per_s", num(rps)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn grid_rps(op: &Value, batch: usize, workers: usize) -> Result<f64, String> {
    let grid = op
        .get("grid")
        .and_then(|x| x.arr().map(|a| a.to_vec()))
        .map_err(|e| format!("grid: {e:#}"))?;
    for e in &grid {
        let b = e.get("batch").and_then(|x| x.usize()).map_err(|e| format!("batch: {e:#}"))?;
        let w =
            e.get("workers").and_then(|x| x.usize()).map_err(|e| format!("workers: {e:#}"))?;
        if b == batch && w == workers {
            return e
                .get("rows_per_s")
                .and_then(|x| x.f64())
                .map_err(|e| format!("rows_per_s: {e:#}"));
        }
    }
    Err(format!("no grid entry for batch {batch} workers {workers}"))
}

fn validate_schema(v: &Value) -> Result<(), String> {
    let get = |key: &str| v.get(key).map_err(|e| format!("{e:#}"));
    if get("kind")?.str().map_err(|e| format!("kind: {e:#}"))? != "bench_sim" {
        return Err("kind != bench_sim".into());
    }
    let version = get("schema_version")?.usize().map_err(|e| format!("schema_version: {e:#}"))?;
    if version != SCHEMA_VERSION {
        return Err(format!("schema_version {version} != {SCHEMA_VERSION}"));
    }
    let provenance = get("provenance")?.str().map_err(|e| format!("provenance: {e:#}"))?;
    if provenance != "estimate" && provenance != "measured" {
        return Err(format!("provenance {provenance:?} not in {{estimate, measured}}"));
    }
    let engine = get("engine")?;
    let want = engine_section();
    if *engine != want {
        return Err(format!(
            "engine drift: committed {} != recomputed {} — the sim geometry \
             changed; rerun `cargo bench --bench bench_sim` and commit the \
             refreshed snapshot",
            engine.to_string(),
            want.to_string()
        ));
    }
    let measured = get("measured")?;
    for op in OPS {
        let sec = measured.get(op).map_err(|e| format!("measured.{op}: {e:#}"))?;
        let scalar = sec
            .get("scalar_b32_rows_per_s")
            .and_then(|x| x.f64())
            .map_err(|e| format!("{op}.scalar_b32_rows_per_s: {e:#}"))?;
        if !scalar.is_finite() || scalar <= 0.0 {
            return Err(format!("{op}.scalar_b32_rows_per_s not positive: {scalar}"));
        }
        let grid = sec
            .get("grid")
            .and_then(|x| x.arr().map(|a| a.to_vec()))
            .map_err(|e| format!("{op}.grid: {e:#}"))?;
        if grid.len() != BATCHES.len() * WORKERS.len() {
            return Err(format!(
                "{op}.grid has {} entries, expected {}",
                grid.len(),
                BATCHES.len() * WORKERS.len()
            ));
        }
        for &b in &BATCHES {
            for &w in &WORKERS {
                let rps = grid_rps(sec, b, w).map_err(|e| format!("{op}: {e}"))?;
                if !rps.is_finite() || rps <= 0.0 {
                    return Err(format!("{op} b={b} w={w}: rows_per_s not positive: {rps}"));
                }
            }
        }
    }
    let speedup = measured
        .get("speedup_generate_b32")
        .and_then(|x| x.f64())
        .map_err(|e| format!("measured.speedup_generate_b32: {e:#}"))?;
    if !speedup.is_finite() || speedup < 2.0 {
        return Err(format!(
            "speedup_generate_b32 {speedup:.2} < 2.0 — the vectorized engine \
             must hold at least 2x over the frozen scalar baseline on \
             batch-32 generate"
        ));
    }
    let gen = measured.get("generate").map_err(|e| format!("{e:#}"))?;
    let scalar = gen
        .get("scalar_b32_rows_per_s")
        .and_then(|x| x.f64())
        .map_err(|e| format!("{e:#}"))?;
    let ratio = grid_rps(gen, SCALAR_BATCH, 1)? / scalar;
    if (speedup - ratio).abs() > 0.01 * ratio {
        return Err(format!(
            "speedup_generate_b32 {speedup:.4} inconsistent with its grid \
             (batch-32 workers-1 / scalar = {ratio:.4})"
        ));
    }
    Ok(())
}

/// `--check`: committed snapshot must be schema-valid, geometry-current
/// and above the speedup floor; prints the committed rows/s tally that
/// ci.sh surfaces in its full-mode report.
fn check_snapshot(path: &str) -> Result<(), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let v = Value::parse(text.trim()).map_err(|e| format!("parsing {path}: {e:#}"))?;
    validate_schema(&v)?;
    let provenance = v.get("provenance").and_then(|x| x.str().map(String::from)).unwrap();
    println!("sim snapshot provenance: {provenance}");
    let measured = v.get("measured").map_err(|e| format!("{e:#}"))?;
    for op in OPS {
        let sec = measured.get(op).map_err(|e| format!("{e:#}"))?;
        let scalar = sec
            .get("scalar_b32_rows_per_s")
            .and_then(|x| x.f64())
            .map_err(|e| format!("{e:#}"))?;
        let fast = grid_rps(sec, SCALAR_BATCH, 1).map_err(|e| format!("{op}: {e}"))?;
        println!(
            "sim rows/s (committed): {op:<9} b32w1 {fast:>10.1}  \
             scalar {scalar:>9.1}  ({:.2}x)",
            fast / scalar
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// main
// ---------------------------------------------------------------------------

fn main() {
    let check = std::env::args().any(|a| a == "--check");
    let path = snapshot_path();
    if check {
        match check_snapshot(&path) {
            Ok(()) => println!("BENCH_SIM.json: schema + engine + speedup floor OK ({path})"),
            Err(e) => {
                eprintln!("BENCH_SIM.json check failed: {e}");
                std::process::exit(1);
            }
        }
        return;
    }

    println!("== sim execution-engine benchmarks ==\n");
    let inp = Inputs::seeded();
    let m = inp.model();
    let (clip_c, kl_coef) = (2.0f32, 0.1f32);

    // generate
    let mut toks = vec![0i32; SCALAR_BATCH * N_GEN];
    let mut lps = vec![0.0f32; SCALAR_BATCH * N_GEN];
    let gen_grid = sweep("generate", |b, w| {
        let gin = GenInput {
            tokens: &inp.tokens_gen[..b * T_PREFILL],
            prompt_len: &inp.plen[..b],
            uniforms: &inp.uniforms[..b * N_GEN],
            temperature: 1.0,
        };
        generate(m, b, &gin, w, &mut toks[..b * N_GEN], &mut lps[..b * N_GEN]);
        std::hint::black_box((&toks, &lps));
    });
    let gen_scalar = measure(SCALAR_BATCH, || {
        scalar_baseline::generate_rows(
            &m,
            SCALAR_BATCH,
            &inp.tokens_gen,
            &inp.plen,
            &inp.uniforms,
            1.0,
            (&mut toks, &mut lps),
        );
        std::hint::black_box((&toks, &lps));
    });
    println!("generate   scalar b={SCALAR_BATCH}: {gen_scalar:>10.1} rows/s");

    // logprobs
    let mut lp_out = vec![0.0f32; SCALAR_BATCH * (T_TRAIN - 1)];
    let lp_grid = sweep("logprobs", |b, w| {
        logprobs(m, b, T_TRAIN, &inp.tokens_train[..b * T_TRAIN], w, &mut lp_out);
        std::hint::black_box(&lp_out);
    });
    let lp_scalar = measure(SCALAR_BATCH, || {
        scalar_baseline::logprob_rows(&m, SCALAR_BATCH, &inp.tokens_train, &mut lp_out);
        std::hint::black_box(&lp_out);
    });
    println!("logprobs   scalar b={SCALAR_BATCH}: {lp_scalar:>10.1} rows/s");

    // grpo_step: merge + adapter grads + dtheta projection per call
    let grpo_grid = sweep("grpo_step", |b, w| {
        let merged = merge_mats(m.mats, &inp.theta);
        let mm =
            SimModel { embed: m.embed, mats: std::array::from_fn(|t| merged[t].as_slice()) };
        let params = GrpoParams {
            behavior: &inp.behavior[..b * (T_TRAIN - 1)],
            advantages: &inp.advantages[..b],
            clip_c,
            kl_coef,
        };
        let (grads, stats) = adapter_grads(
            mm,
            b,
            T_TRAIN,
            &inp.tokens_train[..b * T_TRAIN],
            &inp.mask[..b * (T_TRAIN - 1)],
            Some(&params),
            w,
        );
        std::hint::black_box((project_dtheta(&grads.mats), stats));
    });
    let grpo_scalar = measure(SCALAR_BATCH, || {
        let gin = scalar_baseline::GrpoIn {
            tokens: &inp.tokens_train,
            mask: &inp.mask,
            behavior: &inp.behavior,
            advantages: &inp.advantages,
            clip_c,
            kl_coef,
        };
        std::hint::black_box(scalar_baseline::grpo_step(&m, &inp.theta, SCALAR_BATCH, &gin));
    });
    println!("grpo_step  scalar b={SCALAR_BATCH}: {grpo_scalar:>10.1} rows/s");

    let g32w1 = gen_grid
        .iter()
        .find(|&&(b, w, _)| b == SCALAR_BATCH && w == 1)
        .map(|&(_, _, rps)| rps)
        .unwrap();
    let speedup = g32w1 / gen_scalar;
    println!("\nspeedup (generate b32, vectorized w1 / scalar): {speedup:.2}x");

    let snapshot = obj(vec![
        ("kind", s("bench_sim")),
        ("schema_version", num(SCHEMA_VERSION as f64)),
        ("provenance", s("measured")),
        ("engine", engine_section()),
        (
            "measured",
            obj(vec![
                ("generate", op_section(&gen_grid, gen_scalar)),
                ("logprobs", op_section(&lp_grid, lp_scalar)),
                ("grpo_step", op_section(&grpo_grid, grpo_scalar)),
                ("speedup_generate_b32", num(speedup)),
            ]),
        ),
    ]);
    if let Err(e) = validate_schema(&snapshot) {
        eprintln!("generated snapshot failed its own schema: {e}");
        std::process::exit(1);
    }
    std::fs::write(&path, snapshot.to_string() + "\n").expect("writing snapshot");
    println!("perf snapshot -> {path}");
}
