#!/usr/bin/env bash
# Tier-1 gate (ROADMAP.md): build, tests, docs, formatting. Run from repo
# root.
#
#   ./ci.sh           # full gate
#   ./ci.sh --fast    # skip the release build (debug tests + fmt only)
#
# Integration tests and runtime benches skip themselves gracefully when
# `make artifacts` hasn't produced artifacts/manifest.json; the pure-rust
# suites (scheduler properties, batcher, adapters, tasks, ...) always run.

set -euo pipefail
cd "$(dirname "$0")/rust"

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

if [[ "$FAST" -eq 0 ]]; then
  echo "== cargo build --release =="
  cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

# Perf-trajectory gate: the committed BENCH_runtime.json must stay
# schema-valid and its deterministic sections (occupancy-aware padding
# vs the fixed-geometry baseline) must match a fresh recomputation.
# Skips cleanly when the snapshot is absent; the measured
# device_parallel section is only refreshed intentionally, never here.
if [[ "$FAST" -eq 0 ]]; then
  if [[ -f ../BENCH_runtime.json ]]; then
    echo "== bench_runtime --check (perf snapshot) =="
    cargo bench --bench bench_runtime -- --check
  else
    echo "== BENCH_runtime.json absent — perf-snapshot check skipped =="
  fi
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "== clippy component unavailable — skipped =="
fi

echo "== cargo doc --no-deps (rustdoc warnings denied) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo fmt --check =="
cargo fmt --check

echo "tier-1 gate: OK"
