#!/usr/bin/env bash
# Tier-1 gate (ROADMAP.md): build, tests, docs, formatting. Run from repo
# root.
#
#   ./ci.sh           # full gate
#   ./ci.sh --fast    # skip the release build (debug tests + fmt only)
#
# Integration tests and runtime benches skip themselves gracefully when
# `make artifacts` hasn't produced artifacts/manifest.json; the pure-rust
# suites (scheduler properties, batcher, adapters, tasks, ...) always run.

set -euo pipefail
cd "$(dirname "$0")/rust"

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

if [[ "$FAST" -eq 0 ]]; then
  echo "== cargo build --release =="
  cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "== clippy component unavailable — skipped =="
fi

echo "== cargo doc --no-deps (rustdoc warnings denied) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo fmt --check =="
cargo fmt --check

echo "tier-1 gate: OK"
