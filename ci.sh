#!/usr/bin/env bash
# Tier-1 gate (ROADMAP.md): build, tests, docs, formatting. Run from repo
# root.
#
#   ./ci.sh           # full gate
#   ./ci.sh --fast    # skip the release build (debug tests + fmt only)
#
# PJRT-gated integration tests and runtime benches skip themselves
# gracefully when `make artifacts` hasn't produced artifacts/manifest.json.
# The hermetic suites — everything pure-rust PLUS the full end-to-end
# stack on the sim backend (`tests/e2e_sim.rs`, `*_sim` variants in
# `tests/integration.rs`) — always run: the engine → trainer → serving →
# bench pipeline is exercised on every CI invocation with zero artifacts.

set -euo pipefail
cd "$(dirname "$0")/rust"

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

if [[ "$FAST" -eq 0 ]]; then
  echo "== cargo build --release =="
  cargo build --release
fi

# Unit + doc tests. The two integration targets are deliberately NOT run
# here — report_skips below runs each exactly once with skip accounting
# (running them under the blanket `cargo test` too would execute the
# whole e2e suite twice per gate).
echo "== cargo test -q --lib --bins =="
cargo test -q --lib --bins
echo "== cargo test -q --doc =="
cargo test -q --doc
# the blanket `cargo test` used to compile-check the figure/table
# drivers; keep that coverage now that targets are explicit
echo "== cargo build -q --examples =="
cargo build -q --examples

# Run a test target with skip accounting: any body that early-returns
# prints "skipping: ..." (the require_artifacts! convention), so the
# ran-vs-skipped tally makes silent skips visible in CI logs instead of
# hiding inside a green "ok" line.
report_skips() {
  local label="$1"
  shift
  local out
  out="$("$@" 2>&1)" || { echo "$out"; echo "== $label: FAILED =="; exit 1; }
  local ran skipped
  ran=$(echo "$out" | grep -Eo '[0-9]+ passed' | awk '{s+=$1} END {print s+0}')
  skipped=$(echo "$out" | grep -c 'skipping:' || true)
  echo "== $label: $ran test(s) ran, $skipped skipped =="
  if [[ "$label" == e2e_sim* && "$skipped" -ne 0 ]]; then
    echo "== $label: the hermetic suite must never skip =="
    echo "$out"
    exit 1
  fi
}

# Hermetic e2e gate (ISSUE 5): the sim-backend suite runs in BOTH full
# and --fast modes — no artifacts needed, zero skips tolerated.
echo "== cargo test --test e2e_sim (hermetic sim backend) =="
report_skips "e2e_sim" cargo test --test e2e_sim -- --nocapture
# Chaos gate (ISSUE 9): scripted context death / hangs / transient faults
# through the supervision plane, byte-identity + counter assertions. Also
# hermetic — the e2e_sim* label prefix means zero skips tolerated.
echo "== cargo test --test chaos_sim (deterministic chaos suite) =="
report_skips "e2e_sim_chaos" cargo test --test chaos_sim -- --nocapture
echo "== cargo test --test integration (per-backend, PJRT variants skip without artifacts) =="
report_skips "integration" cargo test --test integration -- --nocapture

# Perf-trajectory gate: the committed BENCH_runtime.json must stay
# schema-valid (device_parallel included — it is measured on the
# hermetic sim backend, so null is never excusable) and its
# deterministic sections (occupancy-aware padding vs the fixed-geometry
# baseline) must match a fresh recomputation. Skips cleanly when the
# snapshot is absent; measured sections are only refreshed
# intentionally, never here.
if [[ "$FAST" -eq 0 ]]; then
  if [[ -f ../BENCH_runtime.json ]]; then
    echo "== bench_runtime --check (perf snapshot) =="
    cargo bench --bench bench_runtime -- --check
  else
    echo "== BENCH_runtime.json absent — perf-snapshot check skipped =="
  fi

  # Sim-engine trajectory gate: BENCH_SIM.json is REQUIRED — the bench
  # is hermetic (pure-rust sim engine vs its frozen scalar baseline),
  # so a missing snapshot has no excuse. `--check` validates the
  # schema, recomputes the engine-geometry echo, enforces the >= 2x
  # batch-32 generate speedup floor, and prints the committed sim
  # rows/s so the gate's tally shows the numbers it is holding.
  if [[ -f ../BENCH_SIM.json ]]; then
    echo "== bench_sim --check (sim engine rows/s snapshot) =="
    cargo bench --bench bench_sim -- --check
  else
    echo "== BENCH_SIM.json missing — run 'cargo bench --bench bench_sim' and commit it =="
    exit 1
  fi

  # Tiered adapter-store gate: BENCH_store.json is REQUIRED — the bench
  # is hermetic (sim backend) and carries the million-tenant capacity
  # claim. `--check` validates the schema, recomputes the packed-record
  # geometry echo, and enforces the capacity gates (stored bytes ==
  # 26 B × tenants exactly, ≤ 128 B/tenant with index, hot-hit checkout
  # cheaper than every merge path).
  if [[ -f ../BENCH_store.json ]]; then
    echo "== bench_store --check (tiered adapter-store snapshot) =="
    cargo bench --bench bench_store -- --check
  else
    echo "== BENCH_store.json missing — run 'cargo bench --bench bench_store' and commit it =="
    exit 1
  fi

  # Open-loop serving gate: BENCH_serve.json is REQUIRED — the bench is
  # hermetic (sim backend) and carries the continuous-vs-wave SLO claim.
  # `--check` validates the schema, recomputes the serving-geometry
  # echo, and enforces the SLO consistency gates: served + shed ==
  # offered and goodput == (served − violations)/horizon per section,
  # zero deadline violations in continuous mode, zero shed at the
  # underload rate, and under overload both modes shed while continuous
  # goodput stays at or above the wave-drain floor.
  if [[ -f ../BENCH_serve.json ]]; then
    echo "== bench_serve --check (open-loop serving SLO snapshot) =="
    cargo bench --bench bench_serve -- --check
  else
    echo "== BENCH_serve.json missing — run 'cargo bench --bench bench_serve' and commit it =="
    exit 1
  fi

  # Async-pipeline gate: BENCH_pipeline.json is REQUIRED — the bench is
  # hermetic (sim backend) and carries the population-scale training
  # claim. `--check` validates the schema, recomputes the run-shape
  # echo, and enforces the pipeline's exact accounting: speedup ==
  # async/sync, consumed == tenants × steps, and zero stale drops at
  # window = staleness + 1. It also prints the snapshot's provenance
  # ("measured" vs "estimate"), as every bench --check now does.
  if [[ -f ../BENCH_pipeline.json ]]; then
    echo "== bench_pipeline --check (async-pipeline steps/s snapshot) =="
    cargo bench --bench bench_pipeline -- --check
  else
    echo "== BENCH_pipeline.json missing — run 'cargo bench --bench bench_pipeline' and commit it =="
    exit 1
  fi
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
else
  echo "== clippy component unavailable — skipped =="
fi

echo "== cargo doc --no-deps (rustdoc warnings denied) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo fmt --check =="
cargo fmt --check

echo "tier-1 gate: OK"
