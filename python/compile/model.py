"""L2 — the JAX model family and every training-step computation.

Decoder-only transformer (RMSNorm, SwiGLU MLP, learned absolute positions),
with the seven per-block linear modules (q,k,v,o,up,gate,down) optionally
routed through the fused adapted-matmul kernel (L1).

Entry points lowered by aot.py (python never runs at request time):

    prefill        (weights, tokens[B,Tp], prompt_len[B]) -> (logits[B,V], kv)
    decode         (weights, kv, pos[B], token[B])        -> (logits[B,V], kv')
    grpo_grad      (weights, factors?, theta, batch...)   -> (dtheta, stats[8])
    sft_grad       (weights, factors?, theta, batch...)   -> (dtheta, stats[8])
    full grads     (weights, batch...)                    -> (dweights..., stats[8])
    pretrain_grad  (weights, tokens, target_mask)         -> (dweights..., stats[8])
    logprobs       (weights, tokens)                      -> logp[B,T-1]
    merge          (adapted weights, factors?, theta)     -> 7 merged tensors

Conventions: all float tensors are f32; all sequences are RIGHT-padded and
positions are absolute in the padded frame, so rollout-time and train-time
log-probs are computed in the same frame (the paper's merged-weights + TIS
trick then only has to absorb numerical differences, not positional ones).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .configs import (MODULES, N_MODULES, VOCAB_SIZE, WEIGHT_NAMES, Scheme,
                      Tier, spec_hash)
from .kernels.tinylora import adapted_matmul

NEG_INF = -1e9
N_STATS = 8


# ---------------------------------------------------------------------------
# Weight init (python mirror of rust's initializer; used by tests and aot
# example-arg construction).
# ---------------------------------------------------------------------------

def weight_init_spec(tier: Tier) -> dict[str, dict]:
    """Init spec per weight tensor — serialised into the manifest so the rust
    pretrainer constructs the exact same distribution family."""
    out_scale = 1.0 / np.sqrt(2 * tier.n_layers)
    spec = {}
    for name, shape in tier.weight_shapes().items():
        if name in ("ln1", "ln2", "ln_f"):
            spec[name] = dict(kind="ones")
        elif name in ("tok_emb", "pos_emb"):
            spec[name] = dict(kind="normal", std=0.02)
        elif name in ("attn_o", "mlp_down"):
            spec[name] = dict(kind="normal", std=float(out_scale / np.sqrt(shape[-2])))
        else:
            spec[name] = dict(kind="normal", std=float(1.0 / np.sqrt(shape[-2])))
    return spec


def init_weights(tier: Tier, seed: int = 0) -> dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    ws = {}
    for name, shape in tier.weight_shapes().items():
        s = weight_init_spec(tier)[name]
        if s["kind"] == "ones":
            ws[name] = jnp.ones(shape, jnp.float32)
        else:
            ws[name] = jnp.asarray(rng.normal(0.0, s["std"], shape), jnp.float32)
    return ws


# ---------------------------------------------------------------------------
# Adapter expansion: flat theta -> per-module (A, M, Bt) operand stacks.
# ---------------------------------------------------------------------------

def p_seed(tier: Tier, scheme: Scheme) -> int:
    """Deterministic seed for the fixed random projection tensors P."""
    return int(spec_hash([tier.name, scheme.tag(), "P"])[:8], 16)


def make_projections(tier: Tier, scheme: Scheme) -> np.ndarray:
    """Fixed random P [L, n_mod, u, r, r], baked as HLO constants (tiny)."""
    rng = np.random.default_rng(p_seed(tier, scheme))
    shape = (tier.n_layers, N_MODULES, scheme.u, scheme.r, scheme.r)
    return (rng.normal(0.0, 1.0, shape) / np.sqrt(scheme.u)).astype(np.float32)


def unpack_theta(theta: jnp.ndarray, segments: list[dict]) -> dict[str, jnp.ndarray]:
    out = {}
    for s in segments:
        out[s["name"]] = jax.lax.dynamic_slice_in_dim(
            theta, s["offset"], s["len"]).reshape(s["shape"])
    return out


def expand_adapters(tier: Tier, scheme: Scheme, theta: jnp.ndarray,
                    factors: Optional[dict[str, jnp.ndarray]]):
    """Return {module: (A [L,d_in,r], M [L,r,r], Bt [L,d_out,r])} or None (full)."""
    if scheme.kind == "full":
        return None
    segs = scheme.theta_segments(tier)
    parts = unpack_theta(theta, segs)
    L = tier.n_layers
    adapters = {}
    if scheme.kind == "tinylora":
        groups = jnp.asarray(scheme.groups(tier), jnp.int32).reshape(L, N_MODULES)
        v_lm = parts["v"][groups]                        # [L, m, u]
        p = jnp.asarray(make_projections(tier, scheme))  # [L, m, u, r, r] const
        code = jnp.einsum("lmu,lmurs->lmrs", v_lm, p)    # [L, m, r, r]
        for mi, m in enumerate(MODULES):
            adapters[m] = (factors[f"us_{m}"], code[:, mi], factors[f"vf_{m}"])
    elif scheme.kind == "lora_xs":
        for m in MODULES:
            adapters[m] = (factors[f"us_{m}"], parts[f"r_{m}"], factors[f"vf_{m}"])
    elif scheme.kind == "lora":
        scale = scheme.lora_alpha / scheme.r
        eye = jnp.broadcast_to(jnp.eye(scheme.r, dtype=jnp.float32) * scale,
                               (L, scheme.r, scheme.r))
        for m in MODULES:
            bt = jnp.swapaxes(parts[f"b_{m}"], 1, 2)     # [L, d_out, r]
            adapters[m] = (parts[f"a_{m}"], eye, bt)
    else:
        raise ValueError(scheme.kind)
    return adapters


# ---------------------------------------------------------------------------
# Transformer blocks.
# ---------------------------------------------------------------------------

def rmsnorm(x, g, eps=1e-6):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def _linear(x2d, w, ad, use_pallas):
    """x2d [rows, d_in] through base weight w with optional adapter (a, m, bt)."""
    if ad is None:
        return x2d @ w
    a, m, bt = ad
    return adapted_matmul(x2d, w, a, m, bt, use_pallas)


def forward(tier: Tier, w: dict, adapters, tokens: jnp.ndarray,
            use_pallas: bool = False) -> jnp.ndarray:
    """Full-sequence causal forward. tokens [B, T] i32 -> logits [B, T, V]."""
    B, T = tokens.shape
    H, hd = tier.n_heads, tier.head_dim
    h = w["tok_emb"][tokens] + w["pos_emb"][:T][None]
    causal = jnp.tril(jnp.ones((T, T), jnp.float32))

    # per-layer scanned weights
    xs = {k: w[k] for k in ("ln1", "attn_q", "attn_k", "attn_v", "attn_o",
                            "ln2", "mlp_up", "mlp_gate", "mlp_down")}
    if adapters is not None:
        for m in MODULES:
            a, mm, bt = adapters[m]
            xs[f"ad_a_{m}"], xs[f"ad_m_{m}"], xs[f"ad_bt_{m}"] = a, mm, bt

    def get_ad(lw, m):
        if adapters is None:
            return None
        return (lw[f"ad_a_{m}"], lw[f"ad_m_{m}"], lw[f"ad_bt_{m}"])

    def block(h, lw):
        x = rmsnorm(h, lw["ln1"])
        x2 = x.reshape(B * T, tier.d)
        q = _linear(x2, lw["attn_q"], get_ad(lw, "q"), use_pallas).reshape(B, T, H, hd)
        k = _linear(x2, lw["attn_k"], get_ad(lw, "k"), use_pallas).reshape(B, T, H, hd)
        v = _linear(x2, lw["attn_v"], get_ad(lw, "v"), use_pallas).reshape(B, T, H, hd)
        scores = jnp.einsum("bthd,bshd->bhts", q, k) / np.sqrt(hd)
        scores = scores + (1.0 - causal) * NEG_INF
        probs = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(B * T, tier.d)
        h = h + _linear(att, lw["attn_o"], get_ad(lw, "o"), use_pallas).reshape(B, T, tier.d)

        x = rmsnorm(h, lw["ln2"]).reshape(B * T, tier.d)
        up = _linear(x, lw["mlp_up"], get_ad(lw, "up"), use_pallas)
        gate = _linear(x, lw["mlp_gate"], get_ad(lw, "gate"), use_pallas)
        y = jax.nn.silu(gate) * up
        h = h + _linear(y, lw["mlp_down"], get_ad(lw, "down"), use_pallas).reshape(B, T, tier.d)
        return h, None

    h, _ = jax.lax.scan(block, h, xs)
    h = rmsnorm(h, w["ln_f"])
    return h @ w["head"]


# ---------------------------------------------------------------------------
# Inference plane: prefill + incremental decode with KV cache (merged
# weights only — the adapter never appears on the request path).
# ---------------------------------------------------------------------------

def prefill(tier: Tier, w: dict, tokens: jnp.ndarray, prompt_len: jnp.ndarray):
    """tokens [B, Tp] right-padded, prompt_len [B] i32.

    Returns (logits [B, V] at the last real token, kv [L,2,B,Tmax,H,hd]).
    Cache positions >= prompt_len hold garbage; the decode mask (pos-based)
    guarantees they are never attended before being overwritten.
    """
    B, Tp = tokens.shape
    H, hd = tier.n_heads, tier.head_dim
    h = w["tok_emb"][tokens] + w["pos_emb"][:Tp][None]
    causal = jnp.tril(jnp.ones((Tp, Tp), jnp.float32))
    xs = {k: w[k] for k in ("ln1", "attn_q", "attn_k", "attn_v", "attn_o",
                            "ln2", "mlp_up", "mlp_gate", "mlp_down")}

    def block(h, lw):
        x = rmsnorm(h, lw["ln1"])
        q = (x @ lw["attn_q"]).reshape(B, Tp, H, hd)
        k = (x @ lw["attn_k"]).reshape(B, Tp, H, hd)
        v = (x @ lw["attn_v"]).reshape(B, Tp, H, hd)
        scores = jnp.einsum("bthd,bshd->bhts", q, k) / np.sqrt(hd)
        probs = jax.nn.softmax(scores + (1.0 - causal) * NEG_INF, axis=-1)
        att = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(B, Tp, tier.d)
        h = h + att @ lw["attn_o"]
        x = rmsnorm(h, lw["ln2"])
        y = jax.nn.silu(x @ lw["mlp_gate"]) * (x @ lw["mlp_up"])
        h = h + y @ lw["mlp_down"]
        pad = ((0, 0), (0, tier.t_max - Tp), (0, 0), (0, 0))
        kv_l = jnp.stack([jnp.pad(k, pad), jnp.pad(v, pad)])  # [2,B,Tmax,H,hd]
        return h, kv_l

    h, kv = jax.lax.scan(block, h, xs)
    h = rmsnorm(h, w["ln_f"])
    logits = h @ w["head"]                                    # [B, Tp, V]
    last = jnp.clip(prompt_len - 1, 0, Tp - 1)
    logits = jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]
    return logits, kv


def decode_step(tier: Tier, w: dict, kv: jnp.ndarray, pos: jnp.ndarray,
                token: jnp.ndarray):
    """One incremental decode step.

    kv [L,2,B,Tmax,H,hd]; pos [B] i32 — the cache slot this token occupies;
    token [B] i32.  Returns (logits [B, V], kv').
    """
    B = token.shape[0]
    H, hd, Tmax = tier.n_heads, tier.head_dim, tier.t_max
    h = w["tok_emb"][token] + w["pos_emb"][pos]               # [B, d]
    valid = (jnp.arange(Tmax)[None, :] <= pos[:, None]).astype(jnp.float32)
    xs = {k: w[k] for k in ("ln1", "attn_q", "attn_k", "attn_v", "attn_o",
                            "ln2", "mlp_up", "mlp_gate", "mlp_down")}
    xs["kv"] = kv

    def write(cache, new, p):
        # cache [Tmax,H,hd], new [H,hd]: write at slot p
        return jax.lax.dynamic_update_slice(cache, new[None], (p, 0, 0))

    def block(h, lw):
        x = rmsnorm(h, lw["ln1"])
        q = (x @ lw["attn_q"]).reshape(B, H, hd)
        k = (x @ lw["attn_k"]).reshape(B, H, hd)
        v = (x @ lw["attn_v"]).reshape(B, H, hd)
        ck = jax.vmap(write)(lw["kv"][0], k, pos)             # [B,Tmax,H,hd]
        cv = jax.vmap(write)(lw["kv"][1], v, pos)
        scores = jnp.einsum("bhd,bshd->bhs", q, ck) / np.sqrt(hd)
        scores = scores + (1.0 - valid[:, None, :]) * NEG_INF
        probs = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("bhs,bshd->bhd", probs, cv).reshape(B, tier.d)
        h = h + att @ lw["attn_o"]
        x = rmsnorm(h, lw["ln2"])
        y = jax.nn.silu(x @ lw["mlp_gate"]) * (x @ lw["mlp_up"])
        h = h + y @ lw["mlp_down"]
        return h, jnp.stack([ck, cv])

    h, kv_new = jax.lax.scan(block, h, xs)
    h = rmsnorm(h, w["ln_f"])
    return h @ w["head"], kv_new


# ---------------------------------------------------------------------------
# Fused generation: the entire rollout loop in one executable.
#
# The xla 0.1.6 PJRT wrapper returns multi-output results as a single tuple
# buffer that cannot be re-fed as an input, so chaining the KV cache on
# device across per-step decode calls is impossible.  Instead the whole
# sampling loop (prefill + S decode steps + inverse-CDF sampling) is lowered
# into ONE executable; rust supplies the uniforms and the temperature and
# gets back (tokens, behavior log-probs).  This is also the fast path: one
# PJRT dispatch per rollout batch instead of S. See EXPERIMENTS.md §Perf.
# ---------------------------------------------------------------------------

def sample_token(logits, u, temp):
    """Inverse-CDF categorical sample at temperature `temp`; greedy if temp<=0.

    Returns (token [B] i32, behavior logp of that token under the actual
    sampling distribution softmax(logits/temp), or the temp->0 limit 0.0
    for greedy)."""
    z = logits / jnp.maximum(temp, 1e-6)
    lp = jax.nn.log_softmax(z, axis=-1)
    cdf = jnp.cumsum(jnp.exp(lp), axis=-1)
    samp = (cdf < u[:, None]).sum(-1).astype(jnp.int32)
    samp = jnp.clip(samp, 0, logits.shape[-1] - 1)
    greedy = jnp.argmax(logits, -1).astype(jnp.int32)
    tok = jnp.where(temp > 0, samp, greedy)
    blp = jnp.take_along_axis(lp, tok[:, None], axis=1)[:, 0]
    blp = jnp.where(temp > 0, blp, 0.0)
    return tok, blp


def generate(tier: Tier, w: dict, prompt: jnp.ndarray, prompt_len: jnp.ndarray,
             uniforms: jnp.ndarray, temp: jnp.ndarray):
    """prompt [B, Tp] right-padded; uniforms [B, S] in [0,1); temp scalar.

    Returns (tokens [B, S] i32, behavior_logp [B, S] f32).  Token i of each
    sequence occupies cache slot prompt_len + i; generation continues past
    EOS (rust masks after the first EOS).  Requires Tp + S <= t_max.
    """
    S = uniforms.shape[1]
    assert tier.t_prefill + S <= tier.t_max + 1
    logits0, kv = prefill(tier, w, prompt, prompt_len)
    tok0, blp0 = sample_token(logits0, uniforms[:, 0], temp)

    def step(carry, u):
        kv, pos, tok = carry
        logits, kv2 = decode_step(tier, w, kv, pos, tok)
        ntok, nblp = sample_token(logits, u, temp)
        return (kv2, pos + 1, ntok), (ntok, nblp)

    (_, _, _), (toks, blps) = jax.lax.scan(
        step, (kv, prompt_len, tok0), uniforms[:, 1:].T)
    out_toks = jnp.concatenate([tok0[:, None], toks.T], axis=1)
    out_blps = jnp.concatenate([blp0[:, None], blps.T], axis=1)
    return out_toks, out_blps


# ---------------------------------------------------------------------------
# Losses.
# ---------------------------------------------------------------------------

def token_logprobs(tier: Tier, w, adapters, tokens, use_pallas=False):
    """Per-token log p(tokens[:,1:]) and the full log-softmax for stats."""
    logits = forward(tier, w, adapters, tokens[:, :-1], use_pallas)
    logp_full = jax.nn.log_softmax(logits, axis=-1)           # [B, T-1, V]
    tgt = tokens[:, 1:]
    logp = jnp.take_along_axis(logp_full, tgt[..., None], axis=-1)[..., 0]
    return logp, logp_full


def grpo_loss(tier: Tier, w, adapters, tokens, target_mask, behavior_logp,
              advantages, clip_c, kl_coef, use_pallas=False):
    """GRPO policy-gradient loss with truncated importance sampling.

    tokens [B, T] i32 (right-padded prompt+response)
    target_mask [B, T-1] — 1 where tokens[:, 1:][b, t] is a scored response token
    behavior_logp [B, T-1] — log-prob of those tokens under the merged
        (inference-plane) weights, recorded during rollout
    advantages [B] — group-relative advantage per sequence
    clip_c — TIS truncation constant; kl_coef — k3 KL penalty weight
    """
    logp, logp_full = token_logprobs(tier, w, adapters, tokens, use_pallas)
    count = jnp.maximum(target_mask.sum(), 1.0)
    delta = logp - behavior_logp
    ratio = jnp.exp(delta)
    w_is = jax.lax.stop_gradient(jnp.minimum(ratio, clip_c))
    pg = -(w_is * logp * advantages[:, None] * target_mask).sum() / count
    # k3 KL estimator of KL(pi || behavior) on sampled tokens
    k3 = jnp.exp(-delta) + delta - 1.0
    kl_pen = (k3 * target_mask).sum() / count
    loss = pg + kl_coef * kl_pen
    # diagnostics
    kl_k1 = (delta * target_mask).sum() / count
    frac_clip = (((ratio > clip_c).astype(jnp.float32)) * target_mask).sum() / count
    ent = (-(jnp.exp(logp_full) * logp_full).sum(-1) * target_mask).sum() / count
    mean_logp = (logp * target_mask).sum() / count
    mean_ratio = (ratio * target_mask).sum() / count
    stats = jnp.stack([loss, pg, kl_k1, kl_pen, mean_ratio, frac_clip, ent,
                       mean_logp])
    return loss, stats


def sft_loss(tier: Tier, w, adapters, tokens, target_mask, use_pallas=False):
    """Next-token CE on gold demonstrations, masked to response tokens."""
    logp, logp_full = token_logprobs(tier, w, adapters, tokens, use_pallas)
    count = jnp.maximum(target_mask.sum(), 1.0)
    loss = -(logp * target_mask).sum() / count
    pred = jnp.argmax(logp_full, axis=-1)
    acc = ((pred == tokens[:, 1:]).astype(jnp.float32) * target_mask).sum() / count
    ent = (-(jnp.exp(logp_full) * logp_full).sum(-1) * target_mask).sum() / count
    stats = jnp.stack([loss, acc, ent, -loss, count, 0.0, 0.0, 0.0])
    return loss, stats


# ---------------------------------------------------------------------------
# Entry-point factories (each returns a python callable over FLAT ordered
# array arguments, ready for jax.jit(...).lower()).  aot.py derives the
# manifest input/output tables from the same builders.
# ---------------------------------------------------------------------------

def weights_from_args(tier: Tier, args) -> dict:
    return {n: a for n, a in zip(WEIGHT_NAMES, args)}


def factor_names() -> list[str]:
    names = []
    for m in MODULES:
        names += [f"us_{m}", f"vf_{m}"]
    return names


def factors_from_args(args) -> dict:
    return {n: a for n, a in zip(factor_names(), args)}


def make_prefill(tier: Tier):
    def fn(*args):
        nw = len(WEIGHT_NAMES)
        w = weights_from_args(tier, args[:nw])
        tokens, prompt_len = args[nw], args[nw + 1]
        logits, kv = prefill(tier, w, tokens, prompt_len)
        return (logits, kv)
    return fn


def make_decode(tier: Tier):
    def fn(*args):
        nw = len(WEIGHT_NAMES)
        w = weights_from_args(tier, args[:nw])
        kv, pos, token = args[nw], args[nw + 1], args[nw + 2]
        logits, kv2 = decode_step(tier, w, kv, pos, token)
        return (logits, kv2)
    return fn


def make_generate(tier: Tier):
    def fn(*args):
        nw = len(WEIGHT_NAMES)
        w = weights_from_args(tier, args[:nw])
        prompt, prompt_len, uniforms, temp = args[nw:nw + 4]
        return generate(tier, w, prompt, prompt_len, uniforms, temp)
    return fn


def _adapter_args(tier: Tier, scheme: Scheme, args, nw):
    """Split (factors?, theta) following the first nw args."""
    if scheme.needs_factors():
        nf = 2 * N_MODULES
        factors = factors_from_args(args[nw:nw + nf])
        theta = args[nw + nf]
        rest = args[nw + nf + 1:]
    else:
        factors, theta, rest = None, args[nw], args[nw + 1:]
    return factors, theta, rest


def make_grpo_grad(tier: Tier, scheme: Scheme, use_pallas: bool):
    nw = len(WEIGHT_NAMES)

    if scheme.kind == "full":
        def fn(*args):
            w = weights_from_args(tier, args[:nw])
            tokens, target_mask, behavior, adv, clip_c, kl_coef = args[nw:nw + 6]

            def loss_fn(w):
                return grpo_loss(tier, w, None, tokens, target_mask, behavior,
                                 adv, clip_c, kl_coef, use_pallas)
            grads, stats = jax.grad(loss_fn, has_aux=True)(w)
            return tuple(grads[n] for n in WEIGHT_NAMES) + (stats,)
        return fn

    def fn(*args):
        w = weights_from_args(tier, args[:nw])
        factors, theta, rest = _adapter_args(tier, scheme, args, nw)
        tokens, target_mask, behavior, adv, clip_c, kl_coef = rest[:6]

        def loss_fn(theta):
            ad = expand_adapters(tier, scheme, theta, factors)
            return grpo_loss(tier, w, ad, tokens, target_mask, behavior,
                             adv, clip_c, kl_coef, use_pallas)
        dtheta, stats = jax.grad(loss_fn, has_aux=True)(theta)
        return (dtheta, stats)
    return fn


def make_sft_grad(tier: Tier, scheme: Scheme, use_pallas: bool):
    nw = len(WEIGHT_NAMES)

    if scheme.kind == "full":
        def fn(*args):
            w = weights_from_args(tier, args[:nw])
            tokens, target_mask = args[nw], args[nw + 1]

            def loss_fn(w):
                return sft_loss(tier, w, None, tokens, target_mask, use_pallas)
            grads, stats = jax.grad(loss_fn, has_aux=True)(w)
            return tuple(grads[n] for n in WEIGHT_NAMES) + (stats,)
        return fn

    def fn(*args):
        w = weights_from_args(tier, args[:nw])
        factors, theta, rest = _adapter_args(tier, scheme, args, nw)
        tokens, target_mask = rest[0], rest[1]

        def loss_fn(theta):
            ad = expand_adapters(tier, scheme, theta, factors)
            return sft_loss(tier, w, ad, tokens, target_mask, use_pallas)
        dtheta, stats = jax.grad(loss_fn, has_aux=True)(theta)
        return (dtheta, stats)
    return fn


def make_pretrain_grad(tier: Tier):
    """Full-param next-token grad (also the full-FT SFT step)."""
    return make_sft_grad(tier, Scheme(kind="full"), use_pallas=False)


def make_logprobs(tier: Tier):
    nw = len(WEIGHT_NAMES)

    def fn(*args):
        w = weights_from_args(tier, args[:nw])
        tokens = args[nw]
        logp, _ = token_logprobs(tier, w, None, tokens)
        return (logp,)
    return fn


# Base weight tensors adapted by the schemes, in MODULES order.
ADAPTED_WEIGHT_NAMES = ("attn_q", "attn_k", "attn_v", "attn_o",
                        "mlp_up", "mlp_gate", "mlp_down")


def make_merge(tier: Tier, scheme: Scheme):
    """Fold the adapter into the 7 adapted weight tensors.

    Inputs: 7 base tensors (ADAPTED_WEIGHT_NAMES order), factors?, theta.
    Outputs: 7 merged tensors in the same order.
    """
    assert scheme.kind != "full"

    def fn(*args):
        base = {m: a for m, a in zip(MODULES, args[:N_MODULES])}
        factors, theta, _ = _adapter_args(tier, scheme, args, N_MODULES)
        ad = expand_adapters(tier, scheme, theta, factors)
        out = []
        for m in MODULES:
            a, mm, bt = ad[m]
            delta = jnp.einsum("lir,lrs,los->lio", a, mm, bt)
            out.append(base[m] + delta)
        return tuple(out)
    return fn
