"""L1 — Pallas kernel for the fused TinyLoRA-adapted matmul.

The paper's compute hot-spot is the adapted linear layer

    y = x @ W + ((x @ A) @ M) @ Bt^T

where the rank-r bottleneck (r <= 8) must never materialise the d_in x d_out
delta.  One kernel serves all three adapter families:

    tinylora:  A = U*Sigma (frozen), M = sum_i v_i P_i, Bt = V (frozen)
    lora_xs:   A = U*Sigma (frozen), M = R (trainable), Bt = V (frozen)
    lora:      A (trainable),        M = I_r,           Bt = B^T (trainable)

The same kernel implements the backward pass: dx is the fused form with
transposed operands, and the small r-dimension cotangents (dM, dA, dBt) are
cheap jnp contractions.

Pallas is lowered with interpret=True (CPU image; real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot run).  The TPU mapping — VMEM
residency of A/M/Bt across the grid, MXU base matmul with VPU rank-r
correction — is documented in DESIGN.md §7 and EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row/col tile sizes. On TPU these would align to (8, 128) VPU lanes and the
# 128x128 MXU tile; in interpret mode they only bound working-set size.
BLOCK_M = 128
BLOCK_N = 128


def _adapted_matmul_kernel(x_ref, w_ref, a_ref, m_ref, bt_ref, o_ref):
    """One (bm, bn) output tile: o = x @ w + ((x @ a) @ m) @ bt^T.

    Per grid step the block specs give us:
      x_ref  [bm, d_in]   — row tile, full reduction dim
      w_ref  [d_in, bn]   — column tile of the frozen weight
      a_ref  [d_in, r]    — whole bottleneck down-projection (VMEM-resident)
      m_ref  [r, r]       — whole adapter code
      bt_ref [bn, r]      — column tile of the bottleneck up-projection
    """
    x = x_ref[...]
    base = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    # rank-r bottleneck: [bm, r] @ [r, r] @ [r, bn] — never materialises d x d
    p = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    corr = jnp.dot(jnp.dot(p, m_ref[...]), bt_ref[...].T,
                   preferred_element_type=jnp.float32)
    o_ref[...] = base + corr


def _pad_to(v: int, b: int) -> int:
    return -(-v // b) * b


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def adapted_matmul_pallas(x, w, a, m, bt, *, block_m: int = BLOCK_M,
                          block_n: int = BLOCK_N):
    """Fused adapted matmul via pallas_call (interpret mode).

    Shapes: x [rows, d_in], w [d_in, d_out], a [d_in, r], m [r, r],
    bt [d_out, r] -> y [rows, d_out].  Handles non-multiple shapes by
    padding rows/cols up to the tile grid (zero padding is exact for this op).
    """
    rows, d_in = x.shape
    d_out = w.shape[1]
    bm = min(block_m, _pad_to(rows, 8))
    bn = min(block_n, _pad_to(d_out, 8))
    rows_p = _pad_to(rows, bm)
    dout_p = _pad_to(d_out, bn)
    xp = jnp.pad(x, ((0, rows_p - rows), (0, 0))) if rows_p != rows else x
    wp = jnp.pad(w, ((0, 0), (0, dout_p - d_out))) if dout_p != d_out else w
    btp = jnp.pad(bt, ((0, dout_p - d_out), (0, 0))) if dout_p != d_out else bt

    grid = (rows_p // bm, dout_p // bn)
    out = pl.pallas_call(
        _adapted_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((d_in, bn), lambda i, j: (0, j)),
            # bottleneck operands: constant index map -> VMEM-resident on TPU
            pl.BlockSpec(a.shape, lambda i, j: (0, 0)),
            pl.BlockSpec(m.shape, lambda i, j: (0, 0)),
            pl.BlockSpec((bn, bt.shape[1]), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows_p, dout_p), jnp.float32),
        interpret=True,
    )(xp, wp, a, m, btp)
    return out[:rows, :d_out]


# ---------------------------------------------------------------------------
# Differentiable wrapper. W is frozen (zero cotangent, DCE'd by XLA); the
# backward pass reuses the same fused kernel for dx and cheap r-dim
# contractions for (da, dm, dbt).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def adapted_matmul(x, w, a, m, bt, use_pallas: bool = True):
    if use_pallas:
        return adapted_matmul_pallas(x, w, a, m, bt)
    return x @ w + ((x @ a) @ m) @ bt.T


def _fwd(x, w, a, m, bt, use_pallas):
    y = adapted_matmul(x, w, a, m, bt, use_pallas)
    return y, (x, w, a, m, bt)


def _bwd(use_pallas, res, g):
    x, w, a, m, bt = res
    # dx = g @ W^T + ((g @ Bt) @ M^T) @ A^T — the same fused form with
    # (W^T, Bt, M^T, A) standing in for (W, A, M, Bt).
    if use_pallas:
        dx = adapted_matmul_pallas(g, w.T, bt, m.T, a)
    else:
        dx = g @ w.T + ((g @ bt) @ m.T) @ a.T
    p = x @ a      # [rows, r]
    q = g @ bt     # [rows, r]
    dm = p.T @ q
    da = x.T @ (q @ m.T)
    dbt = g.T @ (p @ m)
    dw = jnp.zeros_like(w)  # frozen; unused cotangent, DCE'd
    return dx, dw, da, dm, dbt


adapted_matmul.defvjp(_fwd, _bwd)
