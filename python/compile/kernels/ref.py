"""Pure-jnp oracle for the fused adapted-matmul kernel.

This is the CORE correctness reference: every Pallas kernel output (forward
and backward) is checked against these functions by pytest/hypothesis.
"""

from __future__ import annotations

import jax.numpy as jnp


def adapted_matmul_ref(x, w, a, m, bt):
    """y = x @ w + ((x @ a) @ m) @ bt^T

    x  [rows, d_in]     activation
    w  [d_in, d_out]    frozen base weight
    a  [d_in, r]        frozen Us = U*Sigma (tinylora/lora_xs) or trainable A (lora)
    m  [r, r]           adapter code R (tinylora: sum_i v_i P_i; lora: identity)
    bt [d_out, r]       frozen Vf = V (tinylora/lora_xs) or trainable B^T (lora)
    """
    return x @ w + ((x @ a) @ m) @ bt.T


def adapted_matmul_grads_ref(x, w, a, m, bt, g):
    """Cotangents of adapted_matmul_ref w.r.t. (x, a, m, bt) given dy = g."""
    p = x @ a          # [rows, r]
    q = g @ bt         # [rows, r]
    dx = g @ w.T + (q @ m.T) @ a.T
    da = x.T @ (q @ m.T)
    dm = p.T @ q
    dbt = g.T @ (p @ m)
    return dx, da, dm, dbt


def tinylora_code_ref(v_lm, p):
    """R[l,m] = sum_i v_lm[l,m,i] * p[l,m,i]  -> [L, n_mod, r, r]."""
    return jnp.einsum("lmu,lmurs->lmrs", v_lm, p)
