"""Build-time configuration: model tiers, adapter schemes, vocab, theta layout.

This module is the single source of truth for every shape that crosses the
python -> rust boundary.  aot.py serialises the relevant parts into
artifacts/manifest.json; the rust side reads the manifest and never
re-derives shapes on its own (tokenizer charset is cross-checked by a test).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Optional

# ---------------------------------------------------------------------------
# Vocabulary (char-level math tokenizer). Mirrored by rust/src/tokenizer.
# ---------------------------------------------------------------------------

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
CHARS = "0123456789abcdefghijklmnopqrstuvwxyz .,?+-*/=()#<>:'\n"
VOCAB_SIZE = 64  # 3 specials + len(CHARS) = 56, padded to 64 for nice matmuls

assert 3 + len(CHARS) <= VOCAB_SIZE

# ---------------------------------------------------------------------------
# Model size tiers.
# ---------------------------------------------------------------------------

# The seven adapted modules per transformer block (paper: q,k,v,o,up,gate,down).
MODULES = ("q", "k", "v", "o", "up", "gate", "down")
N_MODULES = len(MODULES)

# Names + shapes of the weight pytree, in flattened (manifest) order.
WEIGHT_NAMES = (
    "tok_emb", "pos_emb", "ln1",
    "attn_q", "attn_k", "attn_v", "attn_o",
    "ln2", "mlp_up", "mlp_gate", "mlp_down",
    "ln_f", "head",
)


@dataclasses.dataclass(frozen=True)
class Tier:
    name: str
    d: int          # model width
    n_layers: int
    n_heads: int
    f: int          # mlp hidden width
    t_max: int      # max sequence length (pos-emb length, kv-cache length)
    t_prefill: int  # baked prefill prompt length (right-padded)
    t_train: int    # baked training sequence length

    @property
    def head_dim(self) -> int:
        assert self.d % self.n_heads == 0
        return self.d // self.n_heads

    def weight_shapes(self) -> dict[str, tuple[int, ...]]:
        d, L, f, v, t = self.d, self.n_layers, self.f, VOCAB_SIZE, self.t_max
        return {
            "tok_emb": (v, d),
            "pos_emb": (t, d),
            "ln1": (L, d),
            "attn_q": (L, d, d),
            "attn_k": (L, d, d),
            "attn_v": (L, d, d),
            "attn_o": (L, d, d),
            "ln2": (L, d),
            "mlp_up": (L, d, f),
            "mlp_gate": (L, d, f),
            "mlp_down": (L, f, d),
            "ln_f": (d,),
            "head": (d, v),
        }

    def module_dims(self, m: str) -> tuple[int, int]:
        """(d_in, d_out) of adapted module `m`."""
        d, f = self.d, self.f
        return {
            "q": (d, d), "k": (d, d), "v": (d, d), "o": (d, d),
            "up": (d, f), "gate": (d, f), "down": (f, d),
        }[m]

    def n_params(self) -> int:
        return sum(math.prod(s) for s in self.weight_shapes().values())


TIERS: dict[str, Tier] = {
    t.name: t
    for t in (
        Tier("nano", d=32, n_layers=2, n_heads=2, f=64, t_max=128, t_prefill=64, t_train=128),
        Tier("micro", d=64, n_layers=3, n_heads=4, f=128, t_max=128, t_prefill=64, t_train=128),
        Tier("small", d=96, n_layers=4, n_heads=4, f=192, t_max=128, t_prefill=64, t_train=128),
        Tier("base", d=128, n_layers=4, n_heads=8, f=256, t_max=128, t_prefill=64, t_train=128),
    )
}

# ---------------------------------------------------------------------------
# Adapter schemes.
# ---------------------------------------------------------------------------

SCHEME_KINDS = ("tinylora", "lora_xs", "lora", "full")
TIE_PLANS = ("none", "all", "tiled", "structured")


@dataclasses.dataclass(frozen=True)
class Scheme:
    """A fully-specified adapter parameterisation, baked at lowering time.

    kind:
      tinylora — W' = W + Us (sum_i v_i P_i) Vf^T, trainable v, fixed random P
      lora_xs  — W' = W + Us R Vf^T, trainable R (r x r per module)
      lora     — W' = W + s * A B, trainable A, B
      full     — theta IS the weight pytree (full finetuning)
    tie/n_tie (tinylora only): weight-tying plan across the L*7 modules.
    """

    kind: str
    r: int = 2              # frozen SVD rank (tinylora/lora_xs) or lora rank
    u: int = 1              # tinylora projection dim
    tie: str = "none"       # tinylora tying plan
    n_tie: int = 1          # modules sharing one v (tiled/structured plans)
    lora_alpha: float = 2.0  # lora scale = alpha / r ... recorded, baked

    def tag(self) -> str:
        if self.kind == "tinylora":
            t = {"none": "none", "all": "all"}.get(self.tie, f"{self.tie}{self.n_tie}")
            return f"tinylora_r{self.r}_u{self.u}_{t}"
        if self.kind == "lora_xs":
            return f"xs_r{self.r}"
        if self.kind == "lora":
            return f"lora_r{self.r}"
        return "full"

    # -- group assignment (tinylora weight tying) ---------------------------

    def groups(self, tier: Tier) -> list[int]:
        """Flat module index (l * 7 + m) -> group id."""
        n = tier.n_layers * N_MODULES
        if self.kind != "tinylora":
            return list(range(n))
        if self.tie == "all":
            return [0] * n
        if self.tie == "none":
            return list(range(n))
        if self.tie == "tiled":
            # nearby modules in depth order share, agnostic of type
            return [i // self.n_tie for i in range(n)]
        if self.tie == "structured":
            # nearby modules of the same type share
            n_per_type = -(-tier.n_layers // self.n_tie)  # ceil
            out = []
            for l in range(tier.n_layers):
                for m in range(N_MODULES):
                    out.append(m * n_per_type + l // self.n_tie)
            return out
        raise ValueError(self.tie)

    def n_groups(self, tier: Tier) -> int:
        return max(self.groups(tier)) + 1

    # -- theta layout --------------------------------------------------------

    def theta_segments(self, tier: Tier) -> list[dict]:
        """Ordered flat-theta segment table: name, shape, init spec."""
        L = tier.n_layers
        segs: list[dict] = []
        if self.kind == "tinylora":
            g = self.n_groups(tier)
            segs.append(dict(name="v", shape=[g, self.u], init=dict(kind="zeros")))
        elif self.kind == "lora_xs":
            for m in MODULES:
                segs.append(dict(name=f"r_{m}", shape=[L, self.r, self.r], init=dict(kind="zeros")))
        elif self.kind == "lora":
            for m in MODULES:
                d_in, d_out = tier.module_dims(m)
                segs.append(dict(
                    name=f"a_{m}", shape=[L, d_in, self.r],
                    init=dict(kind="normal", std=1.0 / math.sqrt(d_in)),
                ))
                segs.append(dict(
                    name=f"b_{m}", shape=[L, self.r, d_out],
                    init=dict(kind="zeros"),
                ))
        elif self.kind == "full":
            ws = tier.weight_shapes()
            for nm in WEIGHT_NAMES:
                segs.append(dict(name=nm, shape=list(ws[nm]), init=dict(kind="from_checkpoint")))
        else:
            raise ValueError(self.kind)
        off = 0
        for s in segs:
            s["offset"] = off
            s["len"] = math.prod(s["shape"])
            off += s["len"]
        return segs

    def theta_size(self, tier: Tier) -> int:
        return sum(s["len"] for s in self.theta_segments(tier))

    def needs_factors(self) -> bool:
        """Does this scheme take frozen SVD factors (Us, Vf) as inputs?"""
        return self.kind in ("tinylora", "lora_xs")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def factor_shapes(tier: Tier, r: int) -> list[tuple[str, tuple[int, ...]]]:
    """Frozen SVD factor inputs, in manifest order: us_m [L,d_in,r], vf_m [L,d_out,r]."""
    out = []
    for m in MODULES:
        d_in, d_out = tier.module_dims(m)
        out.append((f"us_{m}", (tier.n_layers, d_in, r)))
        out.append((f"vf_{m}", (tier.n_layers, d_out, r)))
    return out


def spec_hash(obj) -> str:
    return hashlib.sha256(json.dumps(obj, sort_keys=True).encode()).hexdigest()[:16]
