"""AOT lowering: jax entry points -> artifacts/*.hlo.txt + manifest.json.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/gen_hlo.py.

Run via `make artifacts`; incremental — an artifact is re-lowered only when
its spec hash changes.  Python runs only here, never on the request path.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import (CHARS, BOS_ID, EOS_ID, MODULES, N_MODULES, PAD_ID,
                      TIERS, VOCAB_SIZE, WEIGHT_NAMES, Scheme, Tier,
                      factor_shapes, spec_hash)
from . import model as M

F32, S32 = "f32", "s32"

# Baked batch geometry (mirrored in rust via the manifest).
B_ROLL = 32    # rollout/eval batch (prefill + decode)
B_TRAIN = 32   # GRPO/SFT/pretrain batch
B_SERVE = 8    # serving-plane batch
B_TEST = 4     # nano-tier integration-test batch


@dataclasses.dataclass
class Spec:
    """One artifact to lower."""
    name: str
    tier: str
    fn: str                      # prefill|decode|grpo|sft|pretrain|logprobs|merge
    scheme: Optional[Scheme] = None
    batch: int = 0
    seq: int = 0
    use_pallas: bool = False

    def key(self) -> dict:
        d = dict(name=self.name, tier=self.tier, fn=self.fn, batch=self.batch,
                 seq=self.seq, use_pallas=self.use_pallas)
        d["scheme"] = self.scheme.to_json() if self.scheme else None
        return d


# ---------------------------------------------------------------------------
# Input/output signatures.
# ---------------------------------------------------------------------------

def weight_inputs(tier: Tier):
    return [(n, F32, list(s)) for n, s in tier.weight_shapes().items()]


def factor_inputs(tier: Tier, r: int):
    return [(n, F32, list(s)) for n, s in factor_shapes(tier, r)]


def kv_shape(tier: Tier, b: int):
    return [tier.n_layers, 2, b, tier.t_max, tier.n_heads, tier.head_dim]


def signature(spec: Spec):
    """(inputs, outputs) as (name, dtype, shape) lists, in argument order."""
    tier = TIERS[spec.tier]
    sch = spec.scheme
    b, t = spec.batch, spec.seq
    w = weight_inputs(tier)
    if spec.fn == "prefill":
        ins = w + [("tokens", S32, [b, tier.t_prefill]), ("prompt_len", S32, [b])]
        outs = [("logits", F32, [b, VOCAB_SIZE]), ("kv", F32, kv_shape(tier, b))]
    elif spec.fn == "decode":
        ins = w + [("kv", F32, kv_shape(tier, b)), ("pos", S32, [b]),
                   ("token", S32, [b])]
        outs = [("logits", F32, [b, VOCAB_SIZE]), ("kv", F32, kv_shape(tier, b))]
    elif spec.fn == "generate":
        s = spec.seq  # number of sampled tokens
        ins = w + [("prompt", S32, [b, tier.t_prefill]), ("prompt_len", S32, [b]),
                   ("uniforms", F32, [b, s]), ("temp", F32, [])]
        outs = [("tokens", S32, [b, s]), ("behavior_logp", F32, [b, s])]
    elif spec.fn in ("grpo", "sft"):
        ad = []
        if sch.kind != "full":
            if sch.needs_factors():
                ad += factor_inputs(tier, sch.r)
            ad += [("theta", F32, [sch.theta_size(tier)])]
        batch = [("tokens", S32, [b, t]), ("target_mask", F32, [b, t - 1])]
        if spec.fn == "grpo":
            batch += [("behavior_logp", F32, [b, t - 1]), ("advantages", F32, [b]),
                      ("clip_c", F32, []), ("kl_coef", F32, [])]
        ins = w + ad + batch
        if sch.kind == "full":
            outs = [(f"d_{n}", F32, list(s)) for n, s in tier.weight_shapes().items()]
        else:
            outs = [("dtheta", F32, [sch.theta_size(tier)])]
        outs += [("stats", F32, [M.N_STATS])]
    elif spec.fn == "pretrain":
        ins = w + [("tokens", S32, [b, t]), ("target_mask", F32, [b, t - 1])]
        outs = [(f"d_{n}", F32, list(s)) for n, s in tier.weight_shapes().items()]
        outs += [("stats", F32, [M.N_STATS])]
    elif spec.fn == "logprobs":
        ins = w + [("tokens", S32, [b, t])]
        outs = [("logp", F32, [b, t - 1])]
    elif spec.fn == "merge":
        ins = [(n, F32, list(tier.weight_shapes()[n])) for n in M.ADAPTED_WEIGHT_NAMES]
        if sch.needs_factors():
            ins += factor_inputs(tier, sch.r)
        ins += [("theta", F32, [sch.theta_size(tier)])]
        outs = [(f"m_{n}", F32, list(tier.weight_shapes()[n]))
                for n in M.ADAPTED_WEIGHT_NAMES]
    else:
        raise ValueError(spec.fn)
    return ins, outs


def builder(spec: Spec):
    tier = TIERS[spec.tier]
    if spec.fn == "prefill":
        return M.make_prefill(tier)
    if spec.fn == "decode":
        return M.make_decode(tier)
    if spec.fn == "generate":
        return M.make_generate(tier)
    if spec.fn == "grpo":
        return M.make_grpo_grad(tier, spec.scheme, spec.use_pallas)
    if spec.fn == "sft":
        return M.make_sft_grad(tier, spec.scheme, spec.use_pallas)
    if spec.fn == "pretrain":
        return M.make_pretrain_grad(tier)
    if spec.fn == "logprobs":
        return M.make_logprobs(tier)
    if spec.fn == "merge":
        return M.make_merge(tier, spec.scheme)
    raise ValueError(spec.fn)


# ---------------------------------------------------------------------------
# Lowering.
# ---------------------------------------------------------------------------

_DT = {F32: jnp.float32, S32: jnp.int32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # CRITICAL: the default printer elides large constants as `{...}`, which
    # the rust-side HLO text parser silently reads back as zeros — baked
    # tensors (TinyLoRA's random projections P) would vanish.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # metadata carries source_end_line attrs the 0.5.1 parser rejects
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_spec(spec: Spec) -> str:
    ins, _ = signature(spec)
    args = [jax.ShapeDtypeStruct(s, _DT[d]) for _, d, s in ins]
    fn = builder(spec)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# Build matrix.
# ---------------------------------------------------------------------------

def scheme_grid_micro() -> list[Scheme]:
    """Every adapter config the micro-tier experiments (Figs 1,2,4,7,8,9 and
    the Table-2 analogue) need."""
    g: list[Scheme] = []
    # Fig 1/2 pareto: tinylora all-tied u sweep -> theta = u
    for u in (1, 4, 13, 64):
        g.append(Scheme("tinylora", r=2, u=u, tie="all"))
    # untied tinylora fills the 100-1000 param band (theta = 21*u)
    for u in (8, 24):
        g.append(Scheme("tinylora", r=2, u=u, tie="none"))
    # lora-xs band (theta = 21*r^2)
    for r in (2, 4, 8):
        g.append(Scheme("lora_xs", r=r))
    # lora band
    for r in (1, 4):
        g.append(Scheme("lora", r=r))
    g.append(Scheme("full"))
    # Fig 7: frozen-rank ablation at fixed u
    for r in (1, 4, 8):
        g.append(Scheme("tinylora", r=r, u=13, tie="all"))
    # Fig 8/9 + Fig 4: u x tie trade-off (incl. structured vs tiled)
    for u in (1, 4, 16):
        g.append(Scheme("tinylora", r=2, u=u, tie="tiled", n_tie=7))
        g.append(Scheme("tinylora", r=2, u=u, tie="structured", n_tie=3))
    g.append(Scheme("tinylora", r=2, u=16, tie="all"))
    g.append(Scheme("tinylora", r=2, u=16, tie="none"))
    # dedupe by tag
    seen, out = set(), []
    for s in g:
        if s.tag() not in seen:
            seen.add(s.tag())
            out.append(s)
    return out


def scheme_grid_backbone() -> list[Scheme]:
    """Reduced grid for the backbone-scaling figure (Figs 3/6)."""
    return [
        Scheme("tinylora", r=2, u=1, tie="all"),
        Scheme("tinylora", r=2, u=13, tie="all"),
        Scheme("tinylora", r=2, u=8, tie="none"),
        Scheme("lora_xs", r=2),
        Scheme("lora", r=4),
        Scheme("full"),
    ]


# Schemes whose SFT twin is also lowered (Fig 2 / RL-vs-SFT comparison).
SFT_TAGS = {"tinylora_r2_u1_all", "tinylora_r2_u13_all", "tinylora_r2_u64_all",
            "tinylora_r2_u8_none", "xs_r2", "xs_r4", "xs_r8",
            "lora_r1", "lora_r4", "full"}

# Schemes lowered through the Pallas kernel path (the L1 hot-spot); the rest
# use the jnp path, which XLA fuses to the same computation (verified by
# tests/test_kernel.py). Keeping the sweep-grid artifacts on the jnp path
# bounds `make artifacts` latency on the 1-core CPU image.
PALLAS_TAGS = {"tinylora_r2_u13_all"}


def build_specs() -> list[Spec]:
    specs: list[Spec] = []

    def shared(tier: str, b_roll: int, b_train: int):
        t = TIERS[tier]
        tt = t.t_train
        n_gen = t.t_max - t.t_prefill  # sampled tokens per rollout
        specs.append(Spec(f"{tier}.prefill_b{b_roll}", tier, "prefill", batch=b_roll))
        specs.append(Spec(f"{tier}.decode_b{b_roll}", tier, "decode", batch=b_roll))
        specs.append(Spec(f"{tier}.generate_b{b_roll}_s{n_gen}", tier, "generate",
                          batch=b_roll, seq=n_gen))
        specs.append(Spec(f"{tier}.pretrain_b{b_train}_t{tt}", tier, "pretrain",
                          batch=b_train, seq=tt))
        specs.append(Spec(f"{tier}.logprobs_b{b_train}_t{tt}", tier, "logprobs",
                          batch=b_train, seq=tt))

    def scheme_set(tier: str, schemes: list[Scheme], b_train: int, sft_tags=None):
        t = TIERS[tier]
        tt = t.t_train
        for sch in schemes:
            tag = sch.tag()
            pallas = tag in PALLAS_TAGS
            specs.append(Spec(f"{tier}.grpo.{tag}_b{b_train}_t{tt}", tier, "grpo",
                              scheme=sch, batch=b_train, seq=tt, use_pallas=pallas))
            if sft_tags is None or tag in sft_tags:
                specs.append(Spec(f"{tier}.sft.{tag}_b{b_train}_t{tt}", tier, "sft",
                                  scheme=sch, batch=b_train, seq=tt, use_pallas=pallas))
            if sch.kind != "full":
                specs.append(Spec(f"{tier}.merge.{tag}", tier, "merge", scheme=sch))

    # main experiment tier
    shared("micro", B_ROLL, B_TRAIN)
    scheme_set("micro", scheme_grid_micro(), B_TRAIN, SFT_TAGS)
    # serving plane (multi-adapter example + router benches)
    specs.append(Spec(f"micro.prefill_b{B_SERVE}", "micro", "prefill", batch=B_SERVE))
    specs.append(Spec(f"micro.decode_b{B_SERVE}", "micro", "decode", batch=B_SERVE))

    # backbone-scaling tiers
    for tier in ("nano", "small", "base"):
        shared(tier, B_ROLL, B_TRAIN)
        scheme_set(tier, scheme_grid_backbone(), B_TRAIN,
                   sft_tags={"tinylora_r2_u13_all", "full"})

    # nano-tier fast-test variants (integration tests run these)
    t = TIERS["nano"]
    specs.append(Spec(f"nano.prefill_b{B_TEST}", "nano", "prefill", batch=B_TEST))
    specs.append(Spec(f"nano.decode_b{B_TEST}", "nano", "decode", batch=B_TEST))
    specs.append(Spec(f"nano.generate_b{B_TEST}_s{t.t_max - t.t_prefill}", "nano",
                      "generate", batch=B_TEST, seq=t.t_max - t.t_prefill))
    specs.append(Spec(f"micro.generate_b{B_SERVE}_s{t.t_max - t.t_prefill}", "micro",
                      "generate", batch=B_SERVE, seq=t.t_max - t.t_prefill))
    specs.append(Spec(f"nano.pretrain_b{B_TEST}_t{t.t_train}", "nano", "pretrain",
                      batch=B_TEST, seq=t.t_train))
    specs.append(Spec(f"nano.logprobs_b{B_TEST}_t{t.t_train}", "nano", "logprobs",
                      batch=B_TEST, seq=t.t_train))
    test_sch = Scheme("tinylora", r=2, u=13, tie="all")
    specs.append(Spec(f"nano.grpo.{test_sch.tag()}_b{B_TEST}_t{t.t_train}", "nano",
                      "grpo", scheme=test_sch, batch=B_TEST, seq=t.t_train,
                      use_pallas=True))
    specs.append(Spec(f"nano.sft.{test_sch.tag()}_b{B_TEST}_t{t.t_train}", "nano",
                      "sft", scheme=test_sch, batch=B_TEST, seq=t.t_train,
                      use_pallas=True))

    names = [s.name for s in specs]
    assert len(names) == len(set(names)), "duplicate artifact names"
    return specs


# ---------------------------------------------------------------------------
# Manifest.
# ---------------------------------------------------------------------------

def manifest_globals() -> dict:
    tiers = {}
    for name, t in TIERS.items():
        tiers[name] = dict(
            d=t.d, n_layers=t.n_layers, n_heads=t.n_heads, f=t.f,
            t_max=t.t_max, t_prefill=t.t_prefill, t_train=t.t_train,
            head_dim=t.head_dim, n_params=t.n_params(),
            weights=[dict(name=n, shape=list(s),
                          init=M.weight_init_spec(t)[n])
                     for n, s in t.weight_shapes().items()],
            module_dims={m: list(t.module_dims(m)) for m in MODULES},
        )
    return dict(
        version=1,
        vocab=dict(size=VOCAB_SIZE, chars=CHARS, pad=PAD_ID, bos=BOS_ID, eos=EOS_ID),
        modules=list(MODULES),
        weight_names=list(WEIGHT_NAMES),
        n_stats=M.N_STATS,
        batch=dict(roll=B_ROLL, train=B_TRAIN, serve=B_SERVE, test=B_TEST),
        tiers=tiers,
    )


def exe_entry(spec: Spec, fname: str) -> dict:
    tier = TIERS[spec.tier]
    ins, outs = signature(spec)
    e = dict(
        file=fname, fn=spec.fn, tier=spec.tier, batch=spec.batch, seq=spec.seq,
        use_pallas=spec.use_pallas,
        inputs=[dict(name=n, dtype=d, shape=s) for n, d, s in ins],
        outputs=[dict(name=n, dtype=d, shape=s) for n, d, s in outs],
        spec_hash=spec_hash(spec.key()),
    )
    if spec.scheme is not None:
        sch = spec.scheme
        e["scheme"] = sch.to_json()
        e["scheme_tag"] = sch.tag()
        e["theta_size"] = sch.theta_size(tier)
        e["theta_segments"] = sch.theta_segments(tier)
        if sch.kind == "tinylora":
            e["groups"] = sch.groups(tier)
            e["p_seed"] = M.p_seed(tier, sch)
    return e


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="substring filter on names")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    specs = build_specs()
    if args.list:
        for s in specs:
            print(s.name)
        return

    os.makedirs(args.out, exist_ok=True)
    man_path = os.path.join(args.out, "manifest.json")
    old = {}
    if os.path.exists(man_path) and not args.force:
        with open(man_path) as f:
            old = json.load(f).get("executables", {})

    manifest = manifest_globals()
    manifest["executables"] = {}
    n_built = 0
    t_total = time.time()
    for spec in specs:
        fname = spec.name + ".hlo.txt"
        entry = exe_entry(spec, fname)
        fpath = os.path.join(args.out, fname)
        cached = (
            not args.force
            and os.path.exists(fpath)
            and old.get(spec.name, {}).get("spec_hash") == entry["spec_hash"]
        )
        if args.only and args.only not in spec.name:
            if cached:
                manifest["executables"][spec.name] = entry
            continue
        if not cached:
            t0 = time.time()
            text = lower_spec(spec)
            with open(fpath, "w") as f:
                f.write(text)
            n_built += 1
            print(f"[{n_built}] {spec.name}: {len(text)/1024:.0f} KiB "
                  f"in {time.time()-t0:.1f}s", flush=True)
        manifest["executables"][spec.name] = entry

    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"{len(specs)} artifacts ({n_built} lowered) in "
          f"{time.time()-t_total:.1f}s -> {args.out}")


if __name__ == "__main__":
    main()
