"""L1 kernel correctness: Pallas fused adapted-matmul vs the pure-jnp oracle.

Hypothesis sweeps shapes (rows, d_in, d_out, r) and block sizes; every case
checks the forward value and all four cotangents.  This is the core
correctness signal for the kernel — the AOT artifacts embed exactly this
computation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (adapted_matmul_grads_ref, adapted_matmul_ref,
                                 tinylora_code_ref)
from compile.kernels.tinylora import adapted_matmul, adapted_matmul_pallas

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _case(seed, rows, d_in, d_out, r):
    rng = np.random.default_rng(seed)
    return (_rand(rng, rows, d_in), _rand(rng, d_in, d_out),
            _rand(rng, d_in, r), _rand(rng, r, r), _rand(rng, d_out, r))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    rows=st.integers(1, 200),
    d_in=st.sampled_from([8, 32, 64, 96]),
    d_out=st.sampled_from([8, 32, 64, 128]),
    r=st.integers(1, 8),
)
def test_forward_matches_ref(seed, rows, d_in, d_out, r):
    x, w, a, m, bt = _case(seed, rows, d_in, d_out, r)
    got = adapted_matmul_pallas(x, w, a, m, bt)
    want = adapted_matmul_ref(x, w, a, m, bt)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    rows=st.integers(1, 96),
    block_m=st.sampled_from([8, 32, 128]),
    block_n=st.sampled_from([8, 64, 128]),
)
def test_forward_block_size_invariance(seed, rows, block_m, block_n):
    """Tiling must not change the numbers (padding correctness)."""
    x, w, a, m, bt = _case(seed, rows, 64, 96, 2)
    got = adapted_matmul_pallas(x, w, a, m, bt, block_m=block_m, block_n=block_n)
    want = adapted_matmul_ref(x, w, a, m, bt)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    rows=st.integers(2, 64),
    d_in=st.sampled_from([16, 64]),
    d_out=st.sampled_from([16, 96]),
    r=st.integers(1, 6),
    use_pallas=st.booleans(),
)
def test_grads_match_ref(seed, rows, d_in, d_out, r, use_pallas):
    x, w, a, m, bt = _case(seed, rows, d_in, d_out, r)
    g = jnp.ones((rows, d_out), jnp.float32) * 0.5

    def loss(x, a, m, bt):
        return (adapted_matmul(x, w, a, m, bt, use_pallas) * g).sum()

    gx, ga, gm, gbt = jax.grad(loss, argnums=(0, 1, 2, 3))(x, a, m, bt)
    dx, da, dm, dbt = adapted_matmul_grads_ref(x, w, a, m, bt, g)
    for got, want in ((gx, dx), (ga, da), (gm, dm), (gbt, dbt)):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pallas_and_jnp_paths_identical():
    """The two lowering paths of the same artifact must agree exactly."""
    x, w, a, m, bt = _case(7, 130, 64, 128, 2)
    got = adapted_matmul(x, w, a, m, bt, True)
    want = adapted_matmul(x, w, a, m, bt, False)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_zero_code_is_identity():
    """M = 0 (theta = 0 at init) must reproduce the frozen layer exactly."""
    x, w, a, _, bt = _case(3, 40, 32, 64, 4)
    m = jnp.zeros((4, 4), jnp.float32)
    np.testing.assert_allclose(adapted_matmul_pallas(x, w, a, m, bt), x @ w,
                               rtol=1e-6, atol=1e-5)


def test_rank_one_row_broadcast():
    """r=1 reduces to an outer-product update: W + s * a b^T."""
    rng = np.random.default_rng(11)
    x, w = _rand(rng, 9, 16), _rand(rng, 16, 24)
    a, bt = _rand(rng, 16, 1), _rand(rng, 24, 1)
    s = 0.73
    m = jnp.full((1, 1), s, jnp.float32)
    want = x @ (w + s * (a @ bt.T))
    np.testing.assert_allclose(adapted_matmul_pallas(x, w, a, m, bt), want,
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), L=st.integers(1, 4),
       n_mod=st.integers(1, 7), u=st.integers(1, 16), r=st.integers(1, 4))
def test_tinylora_code_linear_in_v(seed, L, n_mod, u, r):
    """R = sum_i v_i P_i is linear in v: R(av + bw) = aR(v) + bR(w)."""
    rng = np.random.default_rng(seed)
    p = _rand(rng, L, n_mod, u, r, r)
    v1 = _rand(rng, L, n_mod, u)
    v2 = _rand(rng, L, n_mod, u)
    lhs = tinylora_code_ref(2.0 * v1 - 3.0 * v2, p)
    rhs = 2.0 * tinylora_code_ref(v1, p) - 3.0 * tinylora_code_ref(v2, p)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)
