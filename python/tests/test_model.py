"""L2 model correctness: forward/prefill/decode consistency, adapter
semantics (zero-init identity, merge == adapted forward), loss gradients
(finite differences), and scheme bookkeeping (Table 1 formulas)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.configs import (MODULES, N_MODULES, TIERS, VOCAB_SIZE, Scheme)

jax.config.update("jax_platform_name", "cpu")

TIER = TIERS["nano"]


def rand_tokens(rng, b, t):
    return jnp.asarray(rng.integers(3, 56, (b, t)), jnp.int32)


def make_factors(tier, r, seed=0, scale=0.3):
    rng = np.random.default_rng(seed)
    f = {}
    for m in MODULES:
        di, do = tier.module_dims(m)
        f[f"us_{m}"] = jnp.asarray(rng.normal(0, scale, (tier.n_layers, di, r)), jnp.float32)
        f[f"vf_{m}"] = jnp.asarray(rng.normal(0, scale, (tier.n_layers, do, r)), jnp.float32)
    return f


@pytest.fixture(scope="module")
def weights():
    return M.init_weights(TIER, seed=0)


# ---------------------------------------------------------------------------
# Inference-plane consistency.
# ---------------------------------------------------------------------------

def test_prefill_matches_forward(weights):
    rng = np.random.default_rng(1)
    tokens = rand_tokens(rng, 3, 24)
    plen = jnp.asarray([24, 10, 1], jnp.int32)
    full = M.forward(TIER, weights, None, tokens)
    logits, _ = M.prefill(TIER, weights, tokens, plen)
    for b, p in enumerate([24, 10, 1]):
        np.testing.assert_allclose(logits[b], full[b, p - 1], rtol=1e-4, atol=1e-4)


def test_decode_chain_matches_forward(weights):
    """Prefill then several decode steps == one full forward pass."""
    rng = np.random.default_rng(2)
    B, Tp, n_new = 2, 12, 6
    prompt = rand_tokens(rng, B, Tp)
    plen = jnp.asarray([Tp, 8], jnp.int32)
    new_tokens = rng.integers(3, 56, (B, n_new)).astype(np.int32)

    _, kv = M.prefill(TIER, weights, prompt, plen)
    seqs = [list(np.asarray(prompt[b][: int(plen[b])])) for b in range(B)]
    pos = np.asarray(plen).copy()
    decode_logits = [[] for _ in range(B)]
    for i in range(n_new):
        tok = jnp.asarray(new_tokens[:, i])
        logits, kv = M.decode_step(TIER, weights, kv, jnp.asarray(pos), tok)
        for b in range(B):
            seqs[b].append(int(new_tokens[b, i]))
            decode_logits[b].append(np.asarray(logits[b]))
        pos += 1

    for b in range(B):
        toks = jnp.asarray(seqs[b], jnp.int32)[None]
        full = M.forward(TIER, weights, None, toks)[0]
        for i in range(n_new):
            t = int(plen[b]) + i
            np.testing.assert_allclose(decode_logits[b][i], full[t], rtol=1e-3,
                                       atol=1e-3)


# ---------------------------------------------------------------------------
# Adapter semantics.
# ---------------------------------------------------------------------------

SCHEMES = [
    Scheme("tinylora", r=2, u=13, tie="all"),
    Scheme("tinylora", r=2, u=4, tie="tiled", n_tie=7),
    Scheme("tinylora", r=1, u=3, tie="structured", n_tie=2),
    Scheme("lora_xs", r=2),
    Scheme("lora", r=4),
]


@pytest.mark.parametrize("scheme", SCHEMES, ids=lambda s: s.tag())
def test_zero_theta_is_base_model(weights, scheme):
    rng = np.random.default_rng(3)
    tokens = rand_tokens(rng, 2, 16)
    factors = make_factors(TIER, scheme.r) if scheme.needs_factors() else None
    theta = jnp.zeros(scheme.theta_size(TIER), jnp.float32)
    # lora init has random A; zero theta still means zero delta because B = 0
    ad = M.expand_adapters(TIER, scheme, theta, factors)
    got = M.forward(TIER, weights, ad, tokens)
    want = M.forward(TIER, weights, None, tokens)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("scheme", SCHEMES, ids=lambda s: s.tag())
def test_merge_matches_adapted_forward(weights, scheme):
    """Forward with merged weights == forward with live adapter (the paper's
    merged-inference equivalence, Fig. 5's KL ~ 0 claim)."""
    rng = np.random.default_rng(4)
    tokens = rand_tokens(rng, 2, 16)
    factors = make_factors(TIER, scheme.r) if scheme.needs_factors() else None
    theta = jnp.asarray(rng.normal(0, 0.05, scheme.theta_size(TIER)), jnp.float32)

    ad = M.expand_adapters(TIER, scheme, theta, factors)
    live = M.forward(TIER, weights, ad, tokens)

    merge = M.make_merge(TIER, scheme)
    base = [weights[n] for n in M.ADAPTED_WEIGHT_NAMES]
    fargs = [factors[n] for n in M.factor_names()] if factors else []
    merged_mats = merge(*base, *fargs, theta)
    w2 = dict(weights)
    for n, mat in zip(M.ADAPTED_WEIGHT_NAMES, merged_mats):
        w2[n] = mat
    merged = M.forward(TIER, w2, None, tokens)
    np.testing.assert_allclose(merged, live, rtol=2e-3, atol=2e-3)


def test_tinylora_tying_reduces_distinct_codes(weights):
    """All-tied scheme must produce identical R across modules."""
    scheme = Scheme("tinylora", r=2, u=5, tie="all")
    factors = make_factors(TIER, 2)
    rng = np.random.default_rng(5)
    theta = jnp.asarray(rng.normal(0, 1, scheme.theta_size(TIER)), jnp.float32)
    v = theta.reshape(1, 5)
    groups = np.asarray(scheme.groups(TIER))
    assert (groups == 0).all()
    # all modules read the same v; codes differ only through P
    ad = M.expand_adapters(TIER, scheme, theta, factors)
    for m in MODULES:
        assert ad[m][1].shape == (TIER.n_layers, 2, 2)


# ---------------------------------------------------------------------------
# Loss gradients (finite differences through the full transformer).
# ---------------------------------------------------------------------------

def _grad_fd_check(loss_fn, theta, eps=1e-3, k=5, rtol=0.08):
    g = jax.grad(loss_fn)(theta)
    rng = np.random.default_rng(0)
    idx = rng.choice(theta.shape[0], size=min(k, theta.shape[0]), replace=False)
    for i in idx:
        e = jnp.zeros_like(theta).at[i].set(eps)
        fd = (loss_fn(theta + e) - loss_fn(theta - e)) / (2 * eps)
        if abs(float(fd)) > 1e-4:
            assert abs(float(g[i]) - float(fd)) <= rtol * abs(float(fd)) + 1e-4, (
                f"idx {i}: analytic {float(g[i])} vs fd {float(fd)}")


def test_grpo_grad_finite_diff(weights):
    """The GRPO gradient stop-gradients the TIS weight w = min(ratio, c), so
    the analytic gradient equals the FD gradient of the *surrogate* loss in
    which w is frozen at theta0 (the standard policy-gradient surrogate)."""
    scheme = Scheme("tinylora", r=2, u=13, tie="all")
    factors = make_factors(TIER, 2)
    rng = np.random.default_rng(6)
    B, T = 2, 20
    tokens = rand_tokens(rng, B, T)
    mask = jnp.asarray(rng.integers(0, 2, (B, T - 1)), jnp.float32)
    behavior = jnp.asarray(rng.normal(-2.0, 0.3, (B, T - 1)), jnp.float32)
    adv = jnp.asarray([1.0, -0.5], jnp.float32)
    theta0 = jnp.asarray(rng.normal(0, 0.02, scheme.theta_size(TIER)), jnp.float32)
    clip_c = jnp.float32(5.0)

    def logp_of(theta):
        ad = M.expand_adapters(TIER, scheme, theta, factors)
        logp, _ = M.token_logprobs(TIER, weights, ad, tokens)
        return logp

    w0 = jnp.minimum(jnp.exp(logp_of(theta0) - behavior), clip_c)
    count = jnp.maximum(mask.sum(), 1.0)

    def surrogate(theta):
        return -(w0 * logp_of(theta) * adv[:, None] * mask).sum() / count

    def grpo(theta):
        ad = M.expand_adapters(TIER, scheme, theta, factors)
        loss, _ = M.grpo_loss(TIER, weights, ad, tokens, mask, behavior, adv,
                              clip_c, jnp.float32(0.0))
        return loss

    g = jax.grad(grpo)(theta0)
    rng2 = np.random.default_rng(0)
    eps = 1e-3
    for i in rng2.choice(theta0.shape[0], size=5, replace=False):
        e = jnp.zeros_like(theta0).at[i].set(eps)
        fd = (surrogate(theta0 + e) - surrogate(theta0 - e)) / (2 * eps)
        if abs(float(fd)) > 1e-4:
            assert abs(float(g[i]) - float(fd)) <= 0.08 * abs(float(fd)) + 1e-4


def test_sft_grad_finite_diff(weights):
    scheme = Scheme("lora_xs", r=2)
    factors = make_factors(TIER, 2)
    rng = np.random.default_rng(7)
    B, T = 2, 20
    tokens = rand_tokens(rng, B, T)
    mask = jnp.asarray(rng.integers(0, 2, (B, T - 1)), jnp.float32)
    theta0 = jnp.asarray(rng.normal(0, 0.02, scheme.theta_size(TIER)), jnp.float32)

    def loss_fn(theta):
        ad = M.expand_adapters(TIER, scheme, theta, factors)
        loss, _ = M.sft_loss(TIER, weights, ad, tokens, mask)
        return loss

    _grad_fd_check(loss_fn, theta0)


def test_grpo_direction_increases_rewarded_logprob(weights):
    """One ascent step along -grad must raise log-prob of positively-advantaged
    sequences: the sign convention end-to-end."""
    scheme = Scheme("tinylora", r=2, u=13, tie="all")
    factors = make_factors(TIER, 2)
    rng = np.random.default_rng(8)
    B, T = 2, 16
    tokens = rand_tokens(rng, B, T)
    mask = jnp.ones((B, T - 1), jnp.float32)
    adv = jnp.asarray([1.0, 0.0], jnp.float32)
    theta = jnp.zeros(scheme.theta_size(TIER), jnp.float32)

    def seq_logp(theta):
        ad = M.expand_adapters(TIER, scheme, theta, factors)
        logp, _ = M.token_logprobs(TIER, weights, ad, tokens)
        return (logp[0] * mask[0]).sum()

    def loss_fn(theta):
        ad = M.expand_adapters(TIER, scheme, theta, factors)
        behavior, _ = M.token_logprobs(TIER, weights, None, tokens)
        loss, _ = M.grpo_loss(TIER, weights, ad, tokens, mask,
                              jax.lax.stop_gradient(behavior), adv,
                              jnp.float32(5.0), jnp.float32(0.0))
        return loss

    g = jax.grad(loss_fn)(theta)
    before = seq_logp(theta)
    after = seq_logp(theta - 0.05 * g / (jnp.linalg.norm(g) + 1e-9))
    assert float(after) > float(before)


# ---------------------------------------------------------------------------
# Scheme bookkeeping — the paper's Table 1 parameter-count formulas.
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(u=st.integers(1, 64), tie=st.sampled_from(["all", "none", "tiled", "structured"]),
       n_tie=st.integers(1, 8), tier=st.sampled_from(list(TIERS)))
def test_table1_tinylora_count(u, tie, n_tie, tier):
    t = TIERS[tier]
    s = Scheme("tinylora", r=2, u=u, tie=tie, n_tie=n_tie)
    n_modules = t.n_layers * N_MODULES
    got = s.theta_size(t)
    if tie == "all":
        assert got == u  # Table 1: down to u (=1) parameters
    elif tie == "none":
        assert got == n_modules * u  # O(n m u)
    else:
        assert got == s.n_groups(t) * u
        assert got <= n_modules * u
        # every module belongs to exactly one group
        gs = s.groups(t)
        assert len(gs) == n_modules
        assert set(gs) == set(range(max(gs) + 1))


@settings(max_examples=20, deadline=None)
@given(r=st.integers(1, 8), tier=st.sampled_from(list(TIERS)))
def test_table1_lora_xs_count(r, tier):
    t = TIERS[tier]
    assert Scheme("lora_xs", r=r).theta_size(t) == t.n_layers * N_MODULES * r * r


@settings(max_examples=20, deadline=None)
@given(r=st.integers(1, 8), tier=st.sampled_from(list(TIERS)))
def test_table1_lora_count(r, tier):
    t = TIERS[tier]
    want = sum(t.n_layers * r * (di + do)
               for di, do in (t.module_dims(m) for m in MODULES))
    assert Scheme("lora", r=r).theta_size(t) == want


def test_table1_full_count():
    for t in TIERS.values():
        assert Scheme("full").theta_size(t) == t.n_params()


def test_theta_segments_are_contiguous():
    for scheme in SCHEMES + [Scheme("full")]:
        segs = scheme.theta_segments(TIER)
        off = 0
        for s in segs:
            assert s["offset"] == off
            off += s["len"]
        assert off == scheme.theta_size(TIER)


# ---------------------------------------------------------------------------
# Fused generation (the in-HLO rollout loop).
# ---------------------------------------------------------------------------

def test_generate_greedy_matches_manual_decode(weights):
    """generate(temp=0) must equal argmax decoding via prefill+decode_step."""
    rng = np.random.default_rng(20)
    B, S = 2, 6
    tokens = rand_tokens(rng, B, TIER.t_prefill)
    plen = jnp.asarray([12, 20], jnp.int32)
    uniforms = jnp.asarray(rng.uniform(size=(B, S)), jnp.float32)
    out_toks, out_lps = M.generate(TIER, weights, tokens, plen, uniforms,
                                   jnp.float32(0.0))
    # manual greedy
    logits, kv = M.prefill(TIER, weights, tokens, plen)
    pos = plen
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    manual = [tok]
    for i in range(S - 1):
        logits, kv = M.decode_step(TIER, weights, kv, pos, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        manual.append(tok)
        pos = pos + 1
    manual = jnp.stack(manual, 1)
    np.testing.assert_array_equal(np.asarray(out_toks), np.asarray(manual))
    # greedy behavior logp is defined as 0
    np.testing.assert_allclose(np.asarray(out_lps), 0.0)


def test_sample_token_distribution():
    """Inverse-CDF sampling matches softmax probabilities."""
    rng = np.random.default_rng(21)
    logits = jnp.asarray([[2.0, 0.0, -1.0, 1.0] + [-1e9] * 60], jnp.float32)
    probs = np.asarray(jax.nn.softmax(logits[0]))
    n = 4000
    counts = np.zeros(64)
    us = rng.uniform(size=n).astype(np.float32)
    for u in us:
        tok, lp = M.sample_token(logits, jnp.asarray([u]), jnp.float32(1.0))
        counts[int(tok[0])] += 1
        # reported logp must match the sampling distribution
        assert abs(float(lp[0]) - float(jnp.log(probs[int(tok[0])]))) < 1e-4
    freq = counts / n
    np.testing.assert_allclose(freq[:4], probs[:4], atol=0.03)


def test_generate_respects_temperature():
    """Higher temperature -> more diverse sampled tokens."""
    w = M.init_weights(TIER, seed=0)
    rng = np.random.default_rng(22)
    B, S = 4, 16
    tokens = rand_tokens(rng, B, TIER.t_prefill)
    plen = jnp.full((B,), 16, jnp.int32)
    uniforms = jnp.asarray(rng.uniform(size=(B, S)), jnp.float32)
    cold, _ = M.generate(TIER, w, tokens, plen, uniforms, jnp.float32(0.05))
    hot, _ = M.generate(TIER, w, tokens, plen, uniforms, jnp.float32(3.0))
    assert len(np.unique(np.asarray(hot))) >= len(np.unique(np.asarray(cold)))
