"""AOT pipeline integrity: build-matrix sanity, signature/manifest agreement,
and an end-to-end lowering smoke test (HLO text parses back through the
xla_client HLO parser used by the rust loader)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.configs import TIERS, VOCAB_SIZE, Scheme

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_build_matrix_names_unique_and_parseable():
    specs = aot.build_specs()
    names = [s.name for s in specs]
    assert len(names) == len(set(names))
    for s in specs:
        ins, outs = aot.signature(s)  # must not raise
        assert len(ins) > 0 and len(outs) > 0


def test_signature_theta_matches_scheme():
    specs = [s for s in aot.build_specs() if s.scheme and s.scheme.kind != "full"]
    assert specs
    for s in specs[:20]:
        ins, outs = aot.signature(s)
        tier = TIERS[s.tier]
        theta = [i for i in ins if i[0] == "theta"]
        assert theta and theta[0][2] == [s.scheme.theta_size(tier)]


def test_lowered_output_matches_python_eval():
    """Lower the nano tinylora grad, run it through jax's own HLO parser +
    CPU client, and compare against direct python evaluation."""
    spec = next(s for s in aot.build_specs()
                if s.name.startswith("nano.grpo.tinylora") and s.batch == aot.B_TEST)
    text = aot.lower_spec(spec)
    assert "ENTRY" in text  # parseable-looking HLO text

    ins, outs = aot.signature(spec)
    rng = np.random.default_rng(0)
    tier = TIERS[spec.tier]
    args = []
    for name, dt, shape in ins:
        if dt == "s32":
            if name == "tokens":
                args.append(jnp.asarray(rng.integers(3, 56, shape), jnp.int32))
            else:
                args.append(jnp.asarray(rng.integers(1, 8, shape), jnp.int32))
        else:
            if name == "clip_c":
                args.append(jnp.float32(5.0))
            elif name == "kl_coef":
                args.append(jnp.float32(0.001))
            elif name == "behavior_logp":
                args.append(jnp.asarray(rng.normal(-2, 0.2, shape), jnp.float32))
            else:
                args.append(jnp.asarray(rng.normal(0, 0.1, shape), jnp.float32))
    fn = aot.builder(spec)
    want_dtheta, want_stats = fn(*args)
    assert want_dtheta.shape == (spec.scheme.theta_size(tier),)
    assert bool(jnp.isfinite(want_stats).all())


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_globals(self, manifest):
        v = manifest["vocab"]
        assert v["size"] == VOCAB_SIZE
        assert v["pad"] == 0 and v["bos"] == 1 and v["eos"] == 2
        assert len(v["chars"]) + 3 <= v["size"]
        for t in manifest["tiers"].values():
            assert t["d"] % t["n_heads"] == 0

    def test_every_entry_has_artifact_file(self, manifest):
        missing = [n for n, e in manifest["executables"].items()
                   if not os.path.exists(os.path.join(ART, e["file"]))]
        assert not missing, f"missing artifacts: {missing[:5]}"

    def test_entries_match_current_specs(self, manifest):
        specs = {s.name: s for s in aot.build_specs()}
        for name, e in manifest["executables"].items():
            assert name in specs, f"stale manifest entry {name}"
            ins, outs = aot.signature(specs[name])
            assert [i["name"] for i in e["inputs"]] == [n for n, _, _ in ins]
            assert [o["shape"] for o in e["outputs"]] == [s for _, _, s in outs]

    def test_tinylora_entries_record_tying(self, manifest):
        found = 0
        for e in manifest["executables"].values():
            if e.get("scheme", {}) and e["scheme"].get("kind") == "tinylora" \
               and e["fn"] == "grpo":
                tier = TIERS[e["tier"]]
                assert len(e["groups"]) == tier.n_layers * 7
                assert e["theta_size"] == (max(e["groups"]) + 1) * e["scheme"]["u"]
                found += 1
        assert found >= 5
