//! Figure 4 — the bit-constrained regime (§6.5): when the budget is
//! *bytes*, which precision and which sharing strategy win?  Compares
//! fp32 / bf16 / fp16 storage of the update and structured vs tiled
//! weight tying at matched byte budgets.
//!
//!     cargo run --release --example fig4_bits

use std::path::Path;

use anyhow::Result;
use tinylora_rl::adapters::packing::Precision;
use tinylora_rl::config::{Args, Dirs};
use tinylora_rl::coordinator::Policy;
use tinylora_rl::experiments::{run, save_outcomes, RunSpec};
use tinylora_rl::metrics::RunLog;
use tinylora_rl::Runtime;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let dirs = Dirs::from_args(&args);
    let tier = args.str("tier", "micro");
    let rt = Runtime::new(Path::new(&dirs.artifacts))?;
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let steps = args.usize("steps", if args.bool("quick") { 25 } else { 40 })?;
    let mut log = RunLog::new(Some(&dirs.results.join("fig4.jsonl")), args.bool("echo"));

    // -- precision sweep at fixed parameter count (all-tied u=16) ------------
    println!("precision sweep (tinylora_r2_u16_all, 16 params):");
    println!("{:<8} {:>8} {:>8} {:>8}", "prec", "bytes", "base", "final");
    let mut outcomes = Vec::new();
    for prec in [Precision::F32, Precision::Bf16, Precision::F16] {
        let mut spec = RunSpec::new(&tier, "tinylora_r2_u16_all", "grpo");
        spec.steps = steps;
        spec.precision = prec;
        spec.eval_n = args.usize("eval-n", 64)?;
        let out = run(&rt, &base, &spec, &dirs.ckpts, &mut log)?;
        println!(
            "{:<8} {:>8} {:>8.3} {:>8.3}",
            prec.name(),
            out.update_bytes,
            out.baseline.accuracy,
            out.final_eval.accuracy
        );
        outcomes.push(out);
    }

    // -- byte-matched comparison: fp32 u=8-ish vs bf16 u=16 ------------------
    // (paper §6.5: "fp32 outperforms bf16 even accounting for its
    // twice-as-large update") — approximate with u=4 fp32 (16B) vs u=16
    // bf16/f16 (32B) vs u=4 bf16 (8B).
    println!("\nbyte-matched points:");
    println!("{:<26} {:<8} {:>8} {:>8}", "scheme", "prec", "bytes", "final");
    for (tag, prec) in [
        ("tinylora_r2_u4_all", Precision::F32),
        ("tinylora_r2_u4_all", Precision::Bf16),
        ("tinylora_r2_u16_all", Precision::Bf16),
        ("tinylora_r2_u16_all", Precision::F16),
    ] {
        let mut spec = RunSpec::new(&tier, tag, "grpo");
        spec.steps = steps;
        spec.precision = prec;
        spec.eval_n = args.usize("eval-n", 64)?;
        let out = run(&rt, &base, &spec, &dirs.ckpts, &mut log)?;
        println!(
            "{:<26} {:<8} {:>8} {:>8.3}",
            tag, prec.name(), out.update_bytes, out.final_eval.accuracy
        );
        outcomes.push(out);
    }

    // -- structured vs tiled sharing at matched params ------------------------
    println!("\nsharing strategy (u=4, matched bytes):");
    println!("{:<30} {:>8} {:>8}", "plan", "params", "final");
    for tag in ["tinylora_r2_u4_tiled7", "tinylora_r2_u4_structured3"] {
        let mut spec = RunSpec::new(&tier, tag, "grpo");
        spec.steps = steps;
        spec.eval_n = args.usize("eval-n", 64)?;
        let out = run(&rt, &base, &spec, &dirs.ckpts, &mut log)?;
        println!("{:<30} {:>8} {:>8.3}", tag, out.trainable_params, out.final_eval.accuracy);
        outcomes.push(out);
    }

    save_outcomes(&dirs.results.join("fig4_outcomes.jsonl"), &outcomes)?;
    Ok(())
}
