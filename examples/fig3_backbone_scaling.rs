//! Figures 3 + 6 — backbone scaling: how many *absolute* parameters does
//! each model size need to reach 95% of its full-FT improvement (Fig. 3),
//! and per-scheme accuracy vs backbone size with untrained baselines
//! (Fig. 6).  The paper's claim: larger models need *fewer* parameters.
//!
//!     cargo run --release --example fig3_backbone_scaling -- [--tiers nano,micro,small,base]

use std::path::Path;

use anyhow::Result;
use tinylora_rl::config::{Args, Dirs};
use tinylora_rl::coordinator::Policy;
use tinylora_rl::experiments::{run_best_lr, save_outcomes, RunOutcome, RunSpec};
use tinylora_rl::metrics::RunLog;
use tinylora_rl::Runtime;

/// Reduced scheme ladder per tier (sorted by params ascending).
const SCHEMES: &[&str] = &[
    "tinylora_r2_u1_all",
    "tinylora_r2_u13_all",
    "tinylora_r2_u8_none",
    "xs_r2",
    "lora_r4",
    "full",
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let dirs = Dirs::from_args(&args);
    let rt = Runtime::new(Path::new(&dirs.artifacts))?;
    let tiers = args.str_list("tiers", &["nano", "micro", "small", "base"]);
    let steps = args.usize("steps", if args.bool("quick") { 25 } else { 40 })?;
    let lrs = args.f32_list("lrs", &[0.0])?;
    let mut log = RunLog::new(Some(&dirs.results.join("fig3.jsonl")), args.bool("echo"));

    let mut all: Vec<RunOutcome> = Vec::new();
    for tier in &tiers {
        let base = match Policy::load_base(&rt, tier, &dirs.ckpts) {
            Ok(b) => b,
            Err(e) => {
                println!("skipping tier {tier}: {e}");
                continue;
            }
        };
        for tag in SCHEMES {
            let mut spec = RunSpec::new(tier, tag, "grpo");
            spec.steps = steps;
            spec.eval_n = args.usize("eval-n", 64)?;
            let out = run_best_lr(&rt, &base, &spec, &lrs, &dirs.ckpts, &mut log)?;
            println!(
                "[{tier}] {:<22} params {:>7} acc {:.3} -> {:.3}",
                tag, out.trainable_params, out.baseline.accuracy, out.final_eval.accuracy
            );
            all.push(out);
        }
    }

    // Fig 6: accuracy vs backbone per scheme (+ dashed baselines)
    println!("\nFigure 6 — accuracy across backbone sizes");
    print!("{:<24}", "scheme");
    for t in &tiers {
        print!(" {:>10}", t);
    }
    println!();
    print!("{:<24}", "(untrained)");
    for t in &tiers {
        let b = all.iter().find(|o| &o.tier == t).map(|o| o.baseline.accuracy).unwrap_or(f32::NAN);
        print!(" {:>10.3}", b);
    }
    println!();
    for tag in SCHEMES {
        print!("{:<24}", tag);
        for t in &tiers {
            match all.iter().find(|o| &o.tier == t && &o.scheme_tag == tag) {
                Some(o) => print!(" {:>10.3}", o.final_eval.accuracy),
                None => print!(" {:>10}", "-"),
            }
        }
        println!();
    }

    // Fig 3: min params to hit 95% of the full-FT gain, per tier
    println!("\nFigure 3 — minimal update size to reach 95% of peak improvement");
    println!("{:<8} {:>12} {:>16} {:>12}", "tier", "model params", "min adapter", "recovery");
    for t in &tiers {
        let Some(full) = all.iter().find(|o| &o.tier == t && o.scheme_tag == "full") else {
            continue;
        };
        let peak = full.final_eval.accuracy;
        let mut rows: Vec<&RunOutcome> =
            all.iter().filter(|o| &o.tier == t && o.scheme_tag != "full").collect();
        rows.sort_by_key(|o| o.trainable_params);
        let hit = rows.iter().find(|o| o.recovery(peak) >= 0.95);
        let model_params = rt.manifest.tier(t)?.n_params;
        match hit {
            Some(o) => println!(
                "{:<8} {:>12} {:>16} {:>11.0}%",
                t,
                model_params,
                o.trainable_params,
                o.recovery(peak) * 100.0
            ),
            None => println!("{:<8} {:>12} {:>16} {:>12}", t, model_params, "(none hit 95%)", "-"),
        }
    }

    save_outcomes(&dirs.results.join("fig3_outcomes.jsonl"), &all)?;
    Ok(())
}
