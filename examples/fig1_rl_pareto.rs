//! Figure 1 — RL accuracy vs trainable-parameter count: the TinyLoRA →
//! LoRA-XS → LoRA interpolation, plus untrained and full-FT dashed
//! baselines.  Also prints Table 1's parameter-count columns for the tier.
//!
//!     cargo run --release --example fig1_rl_pareto -- [--steps 40] [--quick]

use std::path::Path;

use anyhow::Result;
use tinylora_rl::config::{Args, Dirs};
use tinylora_rl::coordinator::Policy;
use tinylora_rl::experiments::{pareto_table, run_best_lr, save_outcomes, RunSpec};
use tinylora_rl::metrics::RunLog;
use tinylora_rl::Runtime;

/// The micro-tier pareto grid: theta spans 1 .. full (Fig. 1's x-axis).
pub const GRID: &[&str] = &[
    "tinylora_r2_u1_all",   // 1 param
    "tinylora_r2_u4_all",   // 4
    "tinylora_r2_u13_all",  // 13 — the headline
    "tinylora_r2_u64_all",  // 64
    "tinylora_r2_u8_none",  // 168
    "tinylora_r2_u24_none", // 504
    "xs_r2",                // 84
    "xs_r4",                // 336
    "xs_r8",                // 1344
    "lora_r1",              // 3264
    "lora_r4",              // 13056
    "full",                 // everything
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let dirs = Dirs::from_args(&args);
    let tier = args.str("tier", "micro");
    let rt = Runtime::new(Path::new(&dirs.artifacts))?;
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let mut log = RunLog::new(Some(&dirs.results.join("fig1.jsonl")), args.bool("echo"));

    let steps = args.usize("steps", if args.bool("quick") { 25 } else { 40 })?;
    let lrs = args.f32_list("lrs", &[0.0])?; // 0.0 => per-size default
    let grid: Vec<String> = if args.bool("quick") {
        ["tinylora_r2_u1_all", "tinylora_r2_u13_all", "xs_r2", "lora_r1", "full"]
            .iter().map(|s| s.to_string()).collect()
    } else {
        args.str_list("schemes", GRID)
    };

    let mut outcomes = Vec::new();
    for tag in &grid {
        let mut spec = RunSpec::new(&tier, tag, "grpo");
        spec.steps = steps;
        spec.eval_n = args.usize("eval-n", 64)?;
        let out = run_best_lr(&rt, &base, &spec, &lrs, &dirs.ckpts, &mut log)?;
        println!(
            "{:<24} params {:>7}  acc {:.3} -> {:.3}  ({:.0}s)",
            tag, out.trainable_params, out.baseline.accuracy, out.final_eval.accuracy, out.wall_secs
        );
        outcomes.push(out);
    }

    println!("\n{}", pareto_table(&format!("Figure 1 — GRPO on gsm8k-syn ({tier})"), &outcomes));
    if let Some(full) = outcomes.iter().find(|o| o.scheme_tag == "full") {
        let full_acc = full.final_eval.accuracy;
        println!("recovery of full-FT improvement (paper's headline metric):");
        for o in &outcomes {
            if o.scheme_tag != "full" {
                println!(
                    "  {:<24} {:>7} params: {:>5.0}%",
                    o.scheme_tag,
                    o.trainable_params,
                    o.recovery(full_acc) * 100.0
                );
            }
        }
    }
    save_outcomes(&dirs.results.join("fig1_outcomes.jsonl"), &outcomes)?;
    println!("saved results/fig1_outcomes.jsonl");
    Ok(())
}
