//! Quickstart: the paper's headline in one file.
//!
//! Pretrains the nano tier from scratch if no checkpoint exists (about a
//! minute on one CPU core), then trains a **13-parameter** TinyLoRA adapter
//! with GRPO on synthetic GSM8K and prints before/after accuracy.
//!
//!     cargo run --release --example quickstart -- [--tier micro] [--steps 30]

use std::path::Path;

use anyhow::Result;
use tinylora_rl::config::{Args, Dirs};
use tinylora_rl::coordinator::{pretrain, PretrainConfig};
use tinylora_rl::experiments::{run, RunSpec};
use tinylora_rl::metrics::RunLog;
use tinylora_rl::weights::WeightSet;
use tinylora_rl::Runtime;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let dirs = Dirs::from_args(&args);
    let tier = args.str("tier", "nano");
    let rt = Runtime::new(Path::new(&dirs.artifacts))?;
    println!("== tinylora-rl quickstart ({tier} tier, PJRT {} backend) ==", rt.platform());

    // 1. base model: load or pretrain from scratch
    let ckpt = WeightSet::ckpt_path(&dirs.ckpts, &tier);
    let base = if ckpt.exists() {
        println!("loading pretrained checkpoint {}", ckpt.display());
        WeightSet::load(&ckpt)?
    } else {
        println!("no checkpoint — pretraining {tier} from scratch on the synthetic corpus...");
        let cfg = PretrainConfig { steps: args.usize("pretrain-steps", 600)?, ..Default::default() };
        let mut log = RunLog::null();
        pretrain(&rt, &tier, &cfg, &dirs.ckpts, &mut log)?;
        WeightSet::load(&ckpt)?
    };
    println!("base model: {} parameters", base.n_params());

    // 2. GRPO with the 13-parameter TinyLoRA adapter (u=13, all modules tied)
    let mut spec = RunSpec::new(&tier, "tinylora_r2_u13_all", "grpo");
    spec.steps = args.usize("steps", 30)?;
    spec.eval_n = args.usize("eval-n", 64)?;
    let mut log = RunLog::new(None, true);
    let out = run(&rt, &base, &spec, &dirs.ckpts, &mut log)?;

    println!("\n== result ==");
    println!("trainable parameters : {}", out.trainable_params);
    println!("update size          : {} bytes (bf16: {} bytes)", out.update_bytes, out.trainable_params * 2);
    println!("gsm8k-syn accuracy   : {:.3} -> {:.3}", out.baseline.accuracy, out.final_eval.accuracy);
    println!("rewarded format rate : {:.3} -> {:.3}", out.baseline.format_rate, out.final_eval.format_rate);
    println!("wall time            : {:.1}s", out.wall_secs);
    Ok(())
}
