//! Figures 8 + 9 — spending a fixed budget: per-module expressivity (u)
//! vs weight tying (n_tie).  The paper's guideline: exhaust u before
//! sharing; tiled sharing beats structured (by-type) sharing.
//!
//!     cargo run --release --example fig8_tying_tradeoff

use std::path::Path;

use anyhow::Result;
use tinylora_rl::config::{Args, Dirs};
use tinylora_rl::coordinator::Policy;
use tinylora_rl::experiments::{run_best_lr, save_outcomes, RunSpec};
use tinylora_rl::metrics::RunLog;
use tinylora_rl::Runtime;

/// (tag, u, tie label) — the u x tie grid lowered for the micro tier.
const GRID: &[(&str, usize, &str)] = &[
    ("tinylora_r2_u1_all", 1, "all"),
    ("tinylora_r2_u4_all", 4, "all"),
    ("tinylora_r2_u16_all", 16, "all"),
    ("tinylora_r2_u1_tiled7", 1, "tiled:7"),
    ("tinylora_r2_u4_tiled7", 4, "tiled:7"),
    ("tinylora_r2_u16_tiled7", 16, "tiled:7"),
    ("tinylora_r2_u1_structured3", 1, "structured:3"),
    ("tinylora_r2_u4_structured3", 4, "structured:3"),
    ("tinylora_r2_u16_structured3", 16, "structured:3"),
    ("tinylora_r2_u16_none", 16, "none"),
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let dirs = Dirs::from_args(&args);
    let tier = args.str("tier", "micro");
    let rt = Runtime::new(Path::new(&dirs.artifacts))?;
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let steps = args.usize("steps", if args.bool("quick") { 25 } else { 40 })?;
    let lrs = args.f32_list("lrs", &[0.0])?;
    let mut log = RunLog::new(Some(&dirs.results.join("fig8.jsonl")), args.bool("echo"));

    println!("Figures 8/9 — u vs tying grid ({tier})");
    println!("{:>4} {:<14} {:>8} {:>8} {:>8}", "u", "tie", "params", "base", "final");
    let mut outcomes = Vec::new();
    for (tag, u, tie) in GRID {
        let mut spec = RunSpec::new(&tier, tag, "grpo");
        spec.steps = steps;
        spec.eval_n = args.usize("eval-n", 64)?;
        let out = run_best_lr(&rt, &base, &spec, &lrs, &dirs.ckpts, &mut log)?;
        println!(
            "{:>4} {:<14} {:>8} {:>8.3} {:>8.3}",
            u, tie, out.trainable_params, out.baseline.accuracy, out.final_eval.accuracy
        );
        outcomes.push(out);
    }

    // the paper's guideline check: at matched params, prefer higher u
    println!("\nmatched-parameter pairs (paper: spend on u before untying):");
    let mut sorted = outcomes.clone();
    sorted.sort_by_key(|o| o.trainable_params);
    for w in sorted.windows(2) {
        if w[0].trainable_params == w[1].trainable_params {
            println!(
                "  {} params: {} ({:.3}) vs {} ({:.3})",
                w[0].trainable_params,
                w[0].scheme_tag,
                w[0].final_eval.accuracy,
                w[1].scheme_tag,
                w[1].final_eval.accuracy
            );
        }
    }
    save_outcomes(&dirs.results.join("fig8_outcomes.jsonl"), &outcomes)?;
    Ok(())
}
