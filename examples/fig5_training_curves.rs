//! Figure 5 — training dynamics on the MATH-style mixture: mean reward,
//! mean response length, and the train-vs-inference KL (the merged-weights
//! + TIS diagnostic that must stay ~0) across steps, for several update
//! sizes.
//!
//!     cargo run --release --example fig5_training_curves -- [--steps 60]

use std::path::Path;

use anyhow::Result;
use tinylora_rl::config::{Args, Dirs};
use tinylora_rl::coordinator::Policy;
use tinylora_rl::experiments::{run, RunSpec};
use tinylora_rl::metrics::RunLog;
use tinylora_rl::Runtime;

const SCHEMES: &[&str] = &["tinylora_r2_u16_all", "tinylora_r2_u8_none", "xs_r4"];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let dirs = Dirs::from_args(&args);
    let tier = args.str("tier", "micro");
    let rt = Runtime::new(Path::new(&dirs.artifacts))?;
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let steps = args.usize("steps", if args.bool("quick") { 30 } else { 60 })?;
    let mut log = RunLog::new(Some(&dirs.results.join("fig5.jsonl")), args.bool("echo"));

    let schemes: Vec<String> = args.str_list("schemes", SCHEMES);
    let mut curves = Vec::new();
    for tag in &schemes {
        let mut spec = RunSpec::new(&tier, tag, "grpo");
        spec.suite = "math-mix".into();
        spec.eval_suite = "math500-syn".into();
        spec.steps = steps;
        spec.kl_coef = 0.001; // the paper's MATH setting
        spec.eval_n = args.usize("eval-n", 64)?;
        let out = run(&rt, &base, &spec, &dirs.ckpts, &mut log)?;
        println!(
            "{tag}: {} params, final acc {:.3}",
            out.trainable_params, out.final_eval.accuracy
        );
        curves.push(out);
    }

    for (panel, get) in [
        ("mean reward", 0usize),
        ("mean response length", 1),
        ("KL(train || inference)", 2),
    ] {
        println!("\nFigure 5 panel: {panel}");
        print!("{:>6}", "step");
        for o in &curves {
            print!(" {:>22}", format!("{}({})", o.scheme_tag, o.trainable_params));
        }
        println!();
        let n = curves.iter().map(|c| c.steps.len()).max().unwrap_or(0);
        for i in (0..n).step_by(5) {
            print!("{:>6}", i);
            for o in &curves {
                match o.steps.get(i) {
                    Some(r) => {
                        let v = match get {
                            0 => r.reward,
                            1 => r.response_len,
                            _ => r.stats.kl_k1,
                        };
                        print!(" {:>22.4}", v);
                    }
                    None => print!(" {:>22}", "-"),
                }
            }
            println!();
        }
    }
    println!("\n(expect: larger updates earn reward faster; KL panel stays ~0 —");
    println!(" the merged-weights rollout + TIS trick is numerically sound)");
    Ok(())
}
