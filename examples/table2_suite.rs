//! Table 2 — math-reasoning performance across update sizes, evaluated on
//! the full benchmark ladder (the synthetic stand-ins for GSM8K / MATH500 /
//! Minerva / Olympiad / AIME / AMC).  Rows: update sizes from (0) untrained
//! through TinyLoRA / LoRA-XS / LoRA to full FT, trained on the math
//! mixture (the paper's SimpleRL protocol, KL coef 0.001).
//!
//!     cargo run --release --example table2_suite -- [--steps 50]

use std::path::Path;

use anyhow::Result;
use tinylora_rl::config::{Args, Dirs};
use tinylora_rl::coordinator::Policy;
use tinylora_rl::eval::{evaluate_suite_ladder, EvalResult};
use tinylora_rl::experiments::{run, save_outcomes, RunSpec};
use tinylora_rl::metrics::RunLog;
use tinylora_rl::Runtime;

const LADDER: &[&str] = &["gsm8k-syn", "math500-syn", "minerva-syn", "olympiad-syn", "aime-syn", "amc-syn"];

/// Update-size ladder, mirroring the paper's 13 / 49 / 196 / ... rows.
const SCHEMES: &[&str] = &[
    "tinylora_r2_u13_all",  // 13
    "tinylora_r2_u64_all",  // 64
    "tinylora_r2_u8_none",  // 168
    "xs_r4",                // 336
    "lora_r1",              // 3264
    "full",
];

fn print_row(label: &str, evs: &[(String, EvalResult)]) {
    print!("{:>12}", label);
    let mut sum = 0.0;
    for s in LADDER {
        let acc = evs.iter().find(|(n, _)| n == s).map(|(_, e)| e.accuracy).unwrap_or(f32::NAN);
        print!(" {:>9.1}", acc * 100.0);
        sum += acc;
    }
    println!(" {:>9.1}", sum / LADDER.len() as f32 * 100.0);
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let dirs = Dirs::from_args(&args);
    let tier = args.str("tier", "micro");
    let rt = Runtime::new(Path::new(&dirs.artifacts))?;
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let steps = args.usize("steps", if args.bool("quick") { 30 } else { 50 })?;
    let eval_n = args.usize("eval-n", 64)?;
    let mut log = RunLog::new(Some(&dirs.results.join("table2.jsonl")), args.bool("echo"));

    println!("Table 2 — {tier} tier, GRPO on math-mix (KL coef 0.001), accuracies x100\n");
    print!("{:>12}", "# params");
    for s in LADDER {
        print!(" {:>9}", s.trim_end_matches("-syn"));
    }
    println!(" {:>9}", "avg");

    // row (0): untrained baseline
    let base_lad = evaluate_suite_ladder(&rt, &tier, &base, eval_n, 777)?;
    print_row("(0)", &base_lad);

    let schemes: Vec<String> = if args.bool("quick") {
        ["tinylora_r2_u13_all", "full"].iter().map(|s| s.to_string()).collect()
    } else {
        args.str_list("schemes", SCHEMES)
    };
    let mut outcomes = Vec::new();
    for tag in &schemes {
        let mut spec = RunSpec::new(&tier, tag, "grpo");
        spec.suite = "math-mix".into();
        spec.eval_suite = "math500-syn".into();
        spec.kl_coef = 0.001;
        spec.steps = steps;
        spec.eval_n = eval_n;
        let out = run(&rt, &base, &spec, &dirs.ckpts, &mut log)?;
        let lad = evaluate_suite_ladder(&rt, &tier, &out.merged, eval_n, 777)?;
        let label = if tag == "full" {
            format!("({})", out.trainable_params)
        } else {
            out.trainable_params.to_string()
        };
        print_row(&label, &lad);
        outcomes.push(out);
    }

    save_outcomes(&dirs.results.join("table2_outcomes.jsonl"), &outcomes)?;
    println!("\nsaved results/table2_outcomes.jsonl");
    Ok(())
}
