//! Figure 7 — frozen SVD rank ablation: performance across r at fixed
//! trainable budget (u=13, all-tied).  The paper finds r=2 best, with
//! larger r *hurting* (more frozen degrees of freedom make the tiny v
//! harder to optimize).
//!
//!     cargo run --release --example fig7_rank_ablation

use std::path::Path;

use anyhow::Result;
use tinylora_rl::config::{Args, Dirs};
use tinylora_rl::coordinator::Policy;
use tinylora_rl::experiments::{run_best_lr, save_outcomes, RunSpec};
use tinylora_rl::metrics::RunLog;
use tinylora_rl::Runtime;

const RANKS: &[(&str, usize)] = &[
    ("tinylora_r1_u13_all", 1),
    ("tinylora_r2_u13_all", 2),
    ("tinylora_r4_u13_all", 4),
    ("tinylora_r8_u13_all", 8),
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let dirs = Dirs::from_args(&args);
    let tier = args.str("tier", "micro");
    let rt = Runtime::new(Path::new(&dirs.artifacts))?;
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let steps = args.usize("steps", if args.bool("quick") { 25 } else { 40 })?;
    let lrs = args.f32_list("lrs", &[0.0])?;
    let mut log = RunLog::new(Some(&dirs.results.join("fig7.jsonl")), args.bool("echo"));

    println!("Figure 7 — frozen rank r at fixed budget (u=13, all-tied), {tier}");
    println!("{:>4} {:>8} {:>8} {:>8}", "r", "params", "base", "final");
    let mut outcomes = Vec::new();
    for (tag, r) in RANKS {
        let mut spec = RunSpec::new(&tier, tag, "grpo");
        spec.steps = steps;
        spec.eval_n = args.usize("eval-n", 64)?;
        let out = run_best_lr(&rt, &base, &spec, &lrs, &dirs.ckpts, &mut log)?;
        println!(
            "{:>4} {:>8} {:>8.3} {:>8.3}",
            r, out.trainable_params, out.baseline.accuracy, out.final_eval.accuracy
        );
        outcomes.push(out);
    }
    save_outcomes(&dirs.results.join("fig7_outcomes.jsonl"), &outcomes)?;
    Ok(())
}
