//! Figure 2 + §6.2 — SFT accuracy vs trainable-parameter count, and the
//! RL-vs-SFT comparison at matched update sizes (the paper's central
//! claim: RL learns in 13 parameters, SFT needs orders of magnitude more).
//!
//!     cargo run --release --example fig2_sft_pareto -- [--compare-rl]

use std::path::Path;

use anyhow::Result;
use tinylora_rl::config::{Args, Dirs};
use tinylora_rl::coordinator::Policy;
use tinylora_rl::experiments::{pareto_table, run_best_lr, save_outcomes, RunSpec};
use tinylora_rl::metrics::RunLog;
use tinylora_rl::util::json::Value;
use tinylora_rl::Runtime;

/// SFT twin of the Fig-1 grid (the subset with lowered SFT artifacts).
const GRID: &[&str] = &[
    "tinylora_r2_u1_all",
    "tinylora_r2_u13_all",
    "tinylora_r2_u64_all",
    "tinylora_r2_u8_none",
    "xs_r2",
    "xs_r4",
    "xs_r8",
    "lora_r1",
    "lora_r4",
    "full",
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let dirs = Dirs::from_args(&args);
    let tier = args.str("tier", "micro");
    let rt = Runtime::new(Path::new(&dirs.artifacts))?;
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let mut log = RunLog::new(Some(&dirs.results.join("fig2.jsonl")), args.bool("echo"));

    let steps = args.usize("steps", if args.bool("quick") { 30 } else { 60 })?;
    let lrs = args.f32_list("lrs", &[0.0])?;
    let grid: Vec<String> = if args.bool("quick") {
        ["tinylora_r2_u13_all", "xs_r4", "lora_r4", "full"].iter().map(|s| s.to_string()).collect()
    } else {
        args.str_list("schemes", GRID)
    };

    let mut outcomes = Vec::new();
    for tag in &grid {
        let mut spec = RunSpec::new(&tier, tag, "sft");
        spec.steps = steps;
        spec.eval_n = args.usize("eval-n", 64)?;
        let out = run_best_lr(&rt, &base, &spec, &lrs, &dirs.ckpts, &mut log)?;
        println!(
            "{:<24} params {:>7}  acc {:.3} -> {:.3}",
            tag, out.trainable_params, out.baseline.accuracy, out.final_eval.accuracy
        );
        outcomes.push(out);
    }

    println!("\n{}", pareto_table(&format!("Figure 2 — SFT on gsm8k-syn ({tier})"), &outcomes));
    save_outcomes(&dirs.results.join("fig2_outcomes.jsonl"), &outcomes)?;

    if args.bool("compare-rl") {
        // join against fig1's saved outcomes at matching schemes (§6.2)
        let path = dirs.results.join("fig1_outcomes.jsonl");
        match std::fs::read_to_string(&path) {
            Ok(text) => {
                println!("\n§6.2 — RL vs SFT at matched update size:");
                println!("{:<24} {:>8} {:>9} {:>9} {:>10}", "scheme", "params", "RL", "SFT", "RL-SFT");
                for line in text.lines() {
                    let v = Value::parse(line)?;
                    let scheme = v.get("scheme")?.str()?.to_string();
                    let rl = v.get("final_acc")?.f64()? as f32;
                    if let Some(sft) = outcomes.iter().find(|o| o.scheme_tag == scheme) {
                        println!(
                            "{:<24} {:>8} {:>9.3} {:>9.3} {:>+10.3}",
                            scheme,
                            sft.trainable_params,
                            rl,
                            sft.final_eval.accuracy,
                            rl - sft.final_eval.accuracy
                        );
                    }
                }
            }
            Err(_) => println!("(run fig1_rl_pareto first for --compare-rl)"),
        }
    }
    Ok(())
}
