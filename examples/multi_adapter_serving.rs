//! Multi-adapter serving — the paper's deployment motivation (§1): a 10x
//! smaller adapter serves 10x more tenants from the same memory.
//!
//! Simulates a multi-tenant workload with Zipf-like popularity over N
//! TinyLoRA adapters (26 bytes each!), served through the dynamic batcher
//! + LRU-merged router, and compares the memory footprint against the
//! "one dedicated merged model per tenant" baseline.
//!
//!     cargo run --release --example multi_adapter_serving -- [--tenants 24] [--requests 96]

use std::path::Path;

use anyhow::Result;
use tinylora_rl::adapters::packing::Precision;
use tinylora_rl::config::{Args, Dirs};
use tinylora_rl::coordinator::Policy;
use tinylora_rl::serving::{AdapterStore, Router};
use tinylora_rl::tasks::generator::SUITES;
use tinylora_rl::util::{Pcg64, Timer};
use tinylora_rl::Runtime;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let dirs = Dirs::from_args(&args);
    let tier = args.str("tier", "micro");
    let rt = Runtime::new(Path::new(&dirs.artifacts))?;
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let n_params = rt.manifest.tier(&tier)?.n_params;

    let tenants = args.usize("tenants", 24)?;
    let n_requests = args.usize("requests", 96)?;
    let max_resident = args.usize("max-resident", 4)?;

    // register tenants: each holds a distinct 13-param adapter
    let mut store = AdapterStore::new(&tier, max_resident);
    let mut rng = Pcg64::new(11);
    for i in 0..tenants {
        let theta: Vec<f32> = (0..13).map(|_| rng.normal() * 0.02).collect();
        store.register(&format!("tenant-{i}"), "tinylora_r2_u13_all", &theta, Precision::Bf16)?;
    }

    let adapter_bytes = store.stored_bytes();
    let model_bytes = store.resident_model_bytes(n_params);
    println!("== memory accounting (the paper's §1 argument) ==");
    println!("tenants                  : {tenants}");
    println!("bytes per adapter        : {} (13 params, bf16)", adapter_bytes / tenants);
    println!("all adapters             : {} bytes", adapter_bytes);
    println!("one merged model         : {} bytes", model_bytes);
    println!("dedicated-model baseline : {} bytes ({} models)", model_bytes * tenants, tenants);
    println!(
        "tinylora serving          : {} bytes ({} resident + adapters) — {:.0}x smaller",
        model_bytes * max_resident + adapter_bytes,
        max_resident,
        (model_bytes * tenants) as f64 / (model_bytes * max_resident + adapter_bytes) as f64
    );

    // drive the workload
    let workers = args.usize("workers", 1)?;
    let mut router = Router::new(&rt, store, base, rt.manifest.batch.serve, 0.2, dirs.ckpts.clone())?;
    let t = Timer::start();
    for i in 0..n_requests {
        // zipf-ish: few tenants get most traffic
        let tenant = ((rng.uniform().powf(2.5) * tenants as f32) as usize).min(tenants - 1);
        let p = SUITES[0].generate(&mut rng);
        router.submit(i as u64, &format!("tenant-{tenant}"), &p);
        router.now += 0.01; // 100 req/s virtual arrival rate
        router.tick(&rt)?;
    }
    if workers > 1 {
        // independent adapter batches decode concurrently (WorkerPool)
        router.drain_parallel(&rt, workers)?;
    } else {
        router.drain(&rt)?;
    }
    let stats = router.stats(); // wall_ms measured inside the router now
    let es = router.engine().stats();

    println!("\n== serving stats ==");
    println!("served requests     : {}", stats.served);
    println!("batches             : {}", stats.batches);
    println!("mean occupancy      : {:.2}", stats.mean_occupancy);
    println!("virtual latency     : mean {:.3}s, p95 {:.3}s", stats.mean_latency, stats.p95_latency);
    println!("merge LRU hit-rate  : {:.2}", stats.merge_hit_rate);
    println!("serve wall time     : {:.0} ms ({:.1} req/s real)", stats.wall_ms, stats.served as f64 / (stats.wall_ms / 1e3));
    println!("end-to-end wall     : {:.0} ms (workers={workers})", t.millis());
    println!("engine              : {} generate calls, {} rows (+{} padding), {:.0} ms decode", es.batches, es.rows, es.padded_rows, es.gen_ms);
    Ok(())
}
