//! End-to-end validation driver (DESIGN.md §6) — proves every layer
//! composes on a real workload:
//!
//!   task gen → tokenizer → AOT artifacts → PJRT runtime → fused-generate
//!   rollouts → verifier → group advantages → grad executable → rust Adam
//!   → merge → eval ladder
//!
//! Pipeline: (1) pretrain the micro tier from scratch on the synthetic math
//! corpus, logging the loss curve; (2) run a few hundred GRPO steps with
//! the 13-parameter TinyLoRA adapter, logging reward / response length /
//! train-vs-infer KL; (3) evaluate the full benchmark ladder before/after.
//! Results land in results/e2e/*.jsonl and are summarised in EXPERIMENTS.md.
//!
//!     cargo run --release --example e2e_train -- [--grpo-steps 200]

use std::path::Path;

use anyhow::Result;
use tinylora_rl::config::{Args, Dirs};
use tinylora_rl::coordinator::{pretrain, PretrainConfig};
use tinylora_rl::eval::evaluate_suite_ladder;
use tinylora_rl::experiments::{run, RunSpec};
use tinylora_rl::metrics::RunLog;
use tinylora_rl::weights::WeightSet;
use tinylora_rl::Runtime;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let dirs = Dirs::from_args(&args);
    let tier = args.str("tier", "micro");
    let scheme = args.str("scheme", "tinylora_r2_u13_all");
    let rt = Runtime::new(Path::new(&dirs.artifacts))?;
    let t_total = tinylora_rl::util::Timer::start();
    std::fs::create_dir_all(dirs.results.join("e2e"))?;

    // ---- stage 1: pretraining ---------------------------------------------
    let ckpt = WeightSet::ckpt_path(&dirs.ckpts, &tier);
    if !ckpt.exists() || args.bool("force-pretrain") {
        println!("== stage 1: pretraining {tier} from scratch ==");
        let cfg = PretrainConfig {
            steps: args.usize("pretrain-steps", 2000)?,
            ..Default::default()
        };
        let mut log = RunLog::new(Some(&dirs.results.join("e2e/pretrain.jsonl")), true);
        let res = pretrain(&rt, &tier, &cfg, &dirs.ckpts, &mut log)?;
        println!("pretraining loss curve (step, loss):");
        for (s, l) in &res.losses {
            println!("  {s:>5} {l:.4}");
        }
    } else {
        println!("== stage 1: using existing checkpoint {} ==", ckpt.display());
    }
    let base = WeightSet::load(&ckpt)?;

    // ---- stage 2: baseline ladder ------------------------------------------
    println!("\n== stage 2: baseline benchmark ladder ==");
    let eval_n = args.usize("eval-n", 64)?;
    let before = evaluate_suite_ladder(&rt, &tier, &base, eval_n, 777)?;
    for (name, ev) in &before {
        println!("  {:<14} acc {:.3} fmt {:.3} len {:>5.1}", name, ev.accuracy, ev.format_rate, ev.mean_response_len);
    }

    // ---- stage 3: GRPO with 13 trainable parameters -------------------------
    println!("\n== stage 3: GRPO, scheme {scheme} ==");
    let mut spec = RunSpec::new(&tier, &scheme, "grpo");
    spec.steps = args.usize("grpo-steps", 200)?;
    spec.eval_n = eval_n;
    spec.lr = args.f32("lr", 0.0)?;
    let mut log = RunLog::new(Some(&dirs.results.join("e2e/grpo.jsonl")), true);
    let out = run(&rt, &base, &spec, &dirs.ckpts, &mut log)?;

    // ---- stage 4: post-training ladder --------------------------------------
    println!("\n== stage 4: post-training benchmark ladder ==");
    let after = evaluate_suite_ladder(&rt, &tier, &out.merged, eval_n, 777)?;
    println!("  {:<14} {:>8} {:>8} {:>8}", "suite", "before", "after", "Δ");
    for ((name, b), (_, a)) in before.iter().zip(&after) {
        println!(
            "  {:<14} {:>8.3} {:>8.3} {:>+8.3}",
            name,
            b.accuracy,
            a.accuracy,
            a.accuracy - b.accuracy
        );
    }

    // ---- summary -------------------------------------------------------------
    println!("\n== e2e summary ==");
    println!("tier {tier} | scheme {scheme} | {} trainable params | {} update bytes",
        out.trainable_params, out.update_bytes);
    println!("reward curve (every 10 steps):");
    for r in out.steps.iter().step_by(10) {
        println!(
            "  step {:>4} reward {:.3} len {:>5.1} fmt {:.2} KL(train||infer) {:+.4}",
            r.step, r.reward, r.response_len, r.format_rate, r.stats.kl_k1
        );
    }
    if let Some(last) = out.steps.last() {
        println!("final: reward {:.3} fmt {:.2}", last.reward, last.format_rate);
    }
    println!(
        "accuracy {:.3} -> {:.3} (+{:.3}) in {:.0}s total",
        out.baseline.accuracy,
        out.final_eval.accuracy,
        out.final_eval.accuracy - out.baseline.accuracy,
        t_total.secs()
    );
    let rs = rt.stats();
    println!(
        "runtime totals: {} executable compiles ({:.1}s), {} dispatches ({:.1}s)",
        rs.compiles,
        rs.compile_ms / 1e3,
        rs.runs,
        rs.run_ms / 1e3
    );
    tinylora_rl::experiments::save_outcomes(&dirs.results.join("e2e/outcome.jsonl"), &[out])?;
    Ok(())
}
